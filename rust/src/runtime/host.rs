//! Host reference backend: executes the model's kernel set directly on
//! [`crate::tensor::Value`]s — no PJRT, no artifacts directory.
//!
//! This is the pure-rust sibling of the jnp oracles in
//! `python/compile/kernels/ref.py`: dense matmul (`qdense`,
//! `qdense_gather`), the epsilon-rule per-weight relevance aggregation
//! (`lrp_dense_rw`), and the two-phase ECQ^x assignment (via
//! [`crate::quant::assign_raw`]), composed into the same artifact surface
//! the AOT pipeline lowers (`<model>_fp_train`, `<model>_ste_train`,
//! `<model>_lrp`, `<model>_eval[_q|_actq]`, `assign_<bucket>`).
//! Execution is driven entirely by the manifest's shape/dtype contract:
//! the dense-layer ladder is recovered from the `p_w<i>`/`idx_w<i>` input
//! signatures, and conv ladders from the `p_c<i>`/`idx_c<i>` signatures
//! plus the `conv_strides`/`conv_pads` (and, for the BatchNorm / pooled /
//! residual models `vgg_*` and `resnet_*`, `conv_bn`/`conv_pool`/
//! `conv_res`) artifact attrs — executed by [`super::host_cnn`] over the
//! im2col lowering in [`crate::linalg::im2col`] plus the pool/BN kernels
//! in [`crate::linalg::pool`] / [`crate::linalg::bn`] (DESIGN.md §2.8).
//!
//! The backend is stateless and every kernel is a deterministic pure
//! function, which is what lets [`crate::runtime::Engine::call_batch`]
//! fan host calls across [`crate::util::pool`] workers with bitwise-stable
//! results.
//!
//! All dense contractions run on the blocked GEMM core in
//! [`crate::linalg`]: bias/ReLU/LRP-scaling passes are fused into the
//! GEMM epilogue, `qdense_gather` dequantizes codebook panels on the fly
//! (never materializing the dense weight matrix), and packing scratch is
//! reused through the per-worker [`Workspace`] threaded in by
//! [`Backend::execute`]. The pre-linalg naive kernels are retained in
//! [`crate::linalg::reference`] and re-exported here (`matmul`,
//! `matmul_tn`, `matmul_nt`) as the conformance oracle.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use super::{ArtifactSpec, Backend, Manifest};
use crate::linalg::{self, with_thread_workspace, Epilogue, Workspace};
use crate::quant::assign_raw;
use crate::tensor::{Tensor, TensorI32, Value};

/// Epsilon-rule stabilizer (python/compile/model.py EPS).
pub const EPS: f32 = 1e-6;
/// Adam defaults (python/compile/model.py adam_update).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

// ---------------------------------------------------------------------------
// kernel set (mirrors python/compile/kernels/ref.py)
// ---------------------------------------------------------------------------

// The naive scalar triple loops this backend originally shipped with are
// retained verbatim in `linalg::reference` as the conformance oracle and
// re-exported here for existing call sites; the hot paths below run on
// the blocked `linalg` core instead.
pub use crate::linalg::reference::{matmul, matmul_nt, matmul_tn};

/// Dense layer `z = a @ w + b` with an optionally fused ReLU — one blocked
/// GEMM with the bias broadcast (and activation) applied in the epilogue,
/// shared by the train forward, both eval paths and the gather path.
pub(crate) fn dense_fwd(
    scratch: &mut Workspace,
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) -> Vec<f32> {
    assert_eq!(bias.len(), n, "qdense bias shape");
    let mut z = vec![0.0f32; m * n];
    let epi = if relu { Epilogue::BiasRelu(bias) } else { Epilogue::Bias(bias) };
    linalg::gemm_nn(scratch, a, w, m, k, n, epi, &mut z);
    z
}

/// Dense layer `y = a @ w + b` (ref.py `qdense_ref`).
pub fn qdense(a: &[f32], w: &[f32], bias: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    with_thread_workspace(|ws| dense_fwd(ws, a, w, bias, m, k, n, false))
}

/// Workspace-threaded core of [`qdense_gather`]: in the fast tier the
/// layer runs through the sparse LUT kernel
/// ([`crate::linalg::lut_gather_nn`]) — codebook indices packed into CSR
/// panels that structurally skip the zero centroid, per-centroid partial
/// sums, one codebook multiply per active centroid — so arithmetic scales
/// with nnz and centroid count instead of dense `k·n` FMAs. Under
/// `--deterministic` (or a codebook wider than
/// [`crate::linalg::MAX_LUT_CENTROIDS`]) the same call routes to the
/// gather-GEMM oracle, preserving the bitwise tier contract; either way
/// the dense `[k,n]` dequantized weight matrix is never materialized. An
/// empty codebook — possible with a corrupt container — is rejected with
/// an error instead of panicking the host path.
pub(crate) fn qdense_gather_ws(
    scratch: &mut Workspace,
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) -> Result<Vec<f32>> {
    assert_eq!(idx.len(), k * n, "qdense_gather idx shape");
    assert_eq!(bias.len(), n, "qdense_gather bias shape");
    if codebook.is_empty() {
        bail!("qdense_gather: empty codebook (corrupt container)");
    }
    // out-of-range indices clamp inside both index packs, matching XLA
    // gather semantics on the PJRT backend
    let mut z = vec![0.0f32; m * n];
    let epi = if relu { Epilogue::BiasRelu(bias) } else { Epilogue::Bias(bias) };
    linalg::lut_gather_nn(scratch, a, idx, codebook, m, k, n, epi, &mut z);
    Ok(z)
}

/// Inference-form dense layer: int32 centroid indices dequantized through
/// a codebook, then `a @ w + b` (ref.py `qdense_gather_ref`). Errors on an
/// empty codebook.
pub fn qdense_gather(
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Result<Vec<f32>> {
    with_thread_workspace(|ws| qdense_gather_ws(ws, a, idx, codebook, bias, m, k, n, false))
}

/// Workspace-threaded core of [`lrp_dense_rw`]: one TN GEMM with the
/// `w ⊙ ·` scaling fused into the store.
pub(crate) fn lrp_dense_rw_ws(
    scratch: &mut Workspace,
    a: &[f32],
    s: &[f32],
    w: &[f32],
    batch: usize,
    din: usize,
    dout: usize,
) -> Vec<f32> {
    assert_eq!(w.len(), din * dout, "lrp_dense_rw weight shape");
    let mut rw = vec![0.0f32; din * dout];
    linalg::gemm_tn(scratch, a, s, batch, din, dout, Epilogue::Scale(w), &mut rw);
    rw
}

/// Per-weight epsilon-rule relevance `R_w = w ⊙ (aᵀ @ s)`
/// (ref.py `lrp_dense_rw_ref`).
pub fn lrp_dense_rw(a: &[f32], s: &[f32], w: &[f32], batch: usize, din: usize, dout: usize) -> Vec<f32> {
    with_thread_workspace(|ws| lrp_dense_rw_ws(ws, a, s, w, batch, din, dout))
}

pub(crate) fn relu_inplace(z: &mut [f32]) {
    for v in z.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// `z + eps·sign(z)` with `sign(0) := 1` (paper Sec. 4.1) — the shared
/// definition lives in [`crate::linalg::stabilize`] (used by the α-β
/// conv rule and the avg-pool LRP redistribution as well).
pub(crate) fn stabilize(z: f32) -> f32 {
    crate::linalg::stabilize(z)
}

/// Round half to even, matching `jnp.round` (f32::round rounds half away).
fn round_ties_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Uniform fake-quantization of a non-negative activation tensor to
/// `levels` levels, per-tensor dynamic scale (model.py `act_fake_quant`).
pub(crate) fn act_fake_quant(x: &mut [f32], levels: f32) {
    let mx = x.iter().fold(0.0f32, |m, &v| m.max(v)).max(1e-8);
    let s = mx / (levels - 1.0);
    for v in x.iter_mut() {
        *v = round_ties_even(*v / s) * s;
    }
}

/// Per-row log-sum-exp (the stabilized softmax denominator).
fn row_lse(row: &[f32]) -> f32 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx
}

/// Mean softmax cross-entropy (the eval hot path: no gradient tensor).
pub(crate) fn softmax_xent_loss(logits: &[f32], y: &[i32], batch: usize, classes: usize) -> f32 {
    let mut loss = 0.0f64;
    for s in 0..batch {
        let row = &logits[s * classes..(s + 1) * classes];
        loss -= (row[y[s] as usize] - row_lse(row)) as f64;
    }
    (loss / batch as f64) as f32
}

/// Mean softmax cross-entropy + its logit gradient `(softmax - onehot)/B`.
pub(crate) fn softmax_xent_grad(
    logits: &[f32],
    y: &[i32],
    batch: usize,
    classes: usize,
) -> (f32, Vec<f32>) {
    let mut loss = 0.0f64;
    let mut grad = vec![0.0f32; batch * classes];
    for s in 0..batch {
        let row = &logits[s * classes..(s + 1) * classes];
        let lse = row_lse(row);
        let yc = y[s] as usize;
        loss -= (row[yc] - lse) as f64;
        let grow = &mut grad[s * classes..(s + 1) * classes];
        for (c, (g, &v)) in grow.iter_mut().zip(row).enumerate() {
            let p = (v - lse).exp();
            *g = (p - if c == yc { 1.0 } else { 0.0 }) / batch as f32;
        }
    }
    ((loss / batch as f64) as f32, grad)
}

/// `Σ_b [argmax(logits_b) == y_b]` with first-max tie-breaking (jnp.argmax).
pub(crate) fn correct_count(logits: &[f32], y: &[i32], batch: usize, classes: usize) -> f32 {
    let mut correct = 0.0f32;
    for s in 0..batch {
        let row = &logits[s * classes..(s + 1) * classes];
        let mut best = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = c;
            }
        }
        if best == y[s] as usize {
            correct += 1.0;
        }
    }
    correct
}

/// One Adam step (model.py `adam_update`), updating `p`/`m`/`v` in place.
pub(crate) fn adam_update(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], t: f32, lr: f32) {
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    for i in 0..p.len() {
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        p[i] -= lr * mh / (vh.sqrt() + ADAM_EPS);
    }
}

// ---------------------------------------------------------------------------
// signature-driven MLP view
// ---------------------------------------------------------------------------

/// Dense-layer ladder recovered from an artifact's input signature (also
/// the dense-head sub-ladder of a CNN signature — see
/// [`super::host_cnn`]).
pub(crate) struct MlpSig {
    /// layer widths `[d0, d1, ..., classes]`
    pub(crate) dims: Vec<usize>,
    pub(crate) batch: usize,
}

impl MlpSig {
    pub(crate) fn layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub(crate) fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }
}

/// Recover the MLP ladder from `<w_prefix><i>` slots (`p_w` for the train
/// and eval artifacts, `idx_w` for the gather eval). Fails with a clear
/// error for non-dense models — conv weights never produce a `w0` chain
/// whose widths match the flattened input.
fn mlp_sig(spec: &ArtifactSpec, w_prefix: &str) -> Result<MlpSig> {
    let shape_of = |name: &str| -> Option<&Vec<usize>> {
        spec.inputs.iter().find(|s| s.name == name).map(|s| &s.shape)
    };
    let x = shape_of("x")
        .with_context(|| format!("artifact {}: no x input", spec.name))?;
    if x.len() != 2 {
        bail!(
            "artifact {}: host backend needs flat [batch, dim] inputs, got {:?} \
             (dense MLP models only)",
            spec.name,
            x
        );
    }
    let (batch, mut din) = (x[0], x[1]);
    let mut dims = vec![din];
    let mut i = 0usize;
    while let Some(shape) = shape_of(&format!("{w_prefix}{i}")) {
        if shape.len() != 2 || shape[0] != din {
            bail!(
                "artifact {}: {w_prefix}{i} shape {:?} does not chain from width {din} \
                 (host backend supports dense MLP models only)",
                spec.name,
                shape
            );
        }
        din = shape[1];
        dims.push(din);
        i += 1;
    }
    if i == 0 {
        bail!(
            "artifact {}: no {w_prefix}0 slot — not a dense MLP signature",
            spec.name
        );
    }
    Ok(MlpSig { dims, batch })
}

/// Name-indexed view over the (already shape-checked) input values.
pub(crate) struct Slots<'a> {
    map: HashMap<&'a str, &'a Value>,
    artifact: &'a str,
}

impl<'a> Slots<'a> {
    pub(crate) fn new(spec: &'a ArtifactSpec, inputs: &'a [Value]) -> Slots<'a> {
        Slots {
            map: spec
                .inputs
                .iter()
                .map(|s| s.name.as_str())
                .zip(inputs.iter())
                .collect(),
            artifact: &spec.name,
        }
    }

    pub(crate) fn get(&self, name: &str) -> Result<&'a Value> {
        self.map
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("artifact {}: missing input {name}", self.artifact))
    }

    pub(crate) fn f32(&self, name: &str) -> Result<&'a [f32]> {
        Ok(&self.get(name)?.as_f32().data)
    }

    pub(crate) fn i32(&self, name: &str) -> Result<&'a [i32]> {
        Ok(&self.get(name)?.as_i32().data)
    }

    pub(crate) fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.get(name)?.as_f32().as_scalar())
    }

    pub(crate) fn has(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }
}

/// Collect the `q_<prefix><i>` quantized-copy slots present in the
/// signature (one entry per layer, `None` where the slot is absent).
pub(crate) fn q_slots<'a>(
    slots: &Slots<'a>,
    prefix: &str,
    n: usize,
) -> Result<Vec<Option<&'a [f32]>>> {
    let mut q: Vec<Option<&'a [f32]>> = vec![None; n];
    for (i, qi) in q.iter_mut().enumerate() {
        let name = format!("q_{prefix}{i}");
        if slots.has(&name) {
            *qi = Some(slots.f32(&name)?);
        }
    }
    Ok(q)
}

/// Fig. 5 step 3: scale the gradients of quantized weights by the
/// magnitude of their (non-zero) centroid value — the single definition
/// of the STE gradient-scaling rule, shared by the MLP and CNN train
/// steps.
pub(crate) fn ste_scale_grads(dws: &mut [Vec<f32>], qs: &[Option<&[f32]>]) {
    for (dw, q) in dws.iter_mut().zip(qs) {
        if let Some(qw) = q {
            for (gv, &qv) in dw.iter_mut().zip(qw.iter()) {
                if qv != 0.0 {
                    *gv *= qv.abs();
                }
            }
        }
    }
}

/// Collect the per-layer `w`/`b` slices from `p_w<i>` / `p_b<i>` slots.
pub(crate) fn dense_params<'a>(
    slots: &Slots<'a>,
    nl: usize,
) -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>)> {
    let mut ws = Vec::with_capacity(nl);
    let mut bs = Vec::with_capacity(nl);
    for i in 0..nl {
        ws.push(slots.f32(&format!("p_w{i}"))?);
        bs.push(slots.f32(&format!("p_b{i}"))?);
    }
    Ok((ws, bs))
}

/// Forward pass keeping every layer input: `acts[i]` feeds layer `i`
/// (`acts[0] = x`, `acts[i>0] = relu(z_{i-1})`, ReLU fused into the GEMM
/// epilogue); returns logits.
pub(crate) fn forward_collect(
    scratch: &mut Workspace,
    sig: &MlpSig,
    ws: &[&[f32]],
    bs: &[&[f32]],
    x: &[f32],
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let nl = sig.layers();
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl);
    acts.push(x.to_vec());
    let mut logits = Vec::new();
    for i in 0..nl {
        let z = dense_fwd(
            scratch,
            &acts[i],
            ws[i],
            bs[i],
            sig.batch,
            sig.dims[i],
            sig.dims[i + 1],
            i + 1 < nl,
        );
        if i + 1 < nl {
            acts.push(z);
        } else {
            logits = z;
        }
    }
    (acts, logits)
}

/// Backward pass of the mean-softmax-xent loss through the dense ladder:
/// returns per-layer `(dW, db)` given the logit gradient `g`, plus — when
/// `input_grad` is set — the gradient at the ladder's input, ReLU-masked
/// by `acts[0]` (the CNN head hands it back to the conv stack, whose last
/// layer owns that ReLU). The ReLU backward mask is fused into the NT
/// GEMM's store throughout.
pub(crate) fn backward(
    scratch: &mut Workspace,
    sig: &MlpSig,
    ws: &[&[f32]],
    acts: &[Vec<f32>],
    mut g: Vec<f32>,
    input_grad: bool,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Option<Vec<f32>>) {
    let nl = sig.layers();
    let mut dws: Vec<Vec<f32>> = vec![Vec::new(); nl];
    let mut dbs: Vec<Vec<f32>> = vec![Vec::new(); nl];
    let mut gin0 = None;
    for i in (0..nl).rev() {
        let (din, dout) = (sig.dims[i], sig.dims[i + 1]);
        let mut dw = vec![0.0f32; din * dout];
        linalg::gemm_tn(scratch, &acts[i], &g, sig.batch, din, dout, Epilogue::None, &mut dw);
        dws[i] = dw;
        let mut db = vec![0.0f32; dout];
        for row in g.chunks_exact(dout) {
            for (d, &gv) in db.iter_mut().zip(row) {
                *d += gv;
            }
        }
        dbs[i] = db;
        if i > 0 || input_grad {
            // relu backward: acts[i] = relu(z_{i-1}) (or, for i == 0 of a
            // CNN head, the last conv layer's ReLU output) — mask is a > 0
            let mut gin = vec![0.0f32; sig.batch * din];
            linalg::gemm_nt(
                scratch,
                &g,
                ws[i],
                sig.batch,
                dout,
                din,
                Epilogue::ReluMask(&acts[i]),
                &mut gin,
            );
            if i > 0 {
                g = gin;
            } else {
                gin0 = Some(gin);
            }
        }
    }
    (dws, dbs, gin0)
}

/// Adam-update the `p_/m_/v_` slots of `grads`' parameters and stage the
/// results in `out` (shared by the MLP and CNN train steps; grads are
/// applied in the given order, which callers keep deterministic).
pub(crate) fn adam_emit(
    spec: &ArtifactSpec,
    slots: &Slots,
    grads: &[(String, Vec<f32>)],
    t: f32,
    lr: f32,
    out: &mut HashMap<String, Value>,
) -> Result<()> {
    for (pname, grad) in grads {
        let mut p = slots.f32(&format!("p_{pname}"))?.to_vec();
        let mut m = slots.f32(&format!("m_{pname}"))?.to_vec();
        let mut v = slots.f32(&format!("v_{pname}"))?.to_vec();
        adam_update(&mut p, &mut m, &mut v, grad, t, lr);
        let shape = spec
            .inputs
            .iter()
            .find(|s| s.name == format!("p_{pname}"))
            .ok_or_else(|| anyhow!("artifact {}: no p_{pname} slot", spec.name))?
            .shape
            .clone();
        out.insert(format!("p_{pname}"), Value::F32(Tensor::new(shape.clone(), p)));
        out.insert(format!("m_{pname}"), Value::F32(Tensor::new(shape.clone(), m)));
        out.insert(format!("v_{pname}"), Value::F32(Tensor::new(shape, v)));
    }
    Ok(())
}

/// Emit outputs in manifest order from a name -> value map.
pub(crate) fn emit(spec: &ArtifactSpec, mut by_name: HashMap<String, Value>) -> Result<Vec<Value>> {
    spec.outputs
        .iter()
        .map(|o| {
            by_name
                .remove(&o.name)
                .ok_or_else(|| anyhow!("artifact {}: host produced no output {}", spec.name, o.name))
        })
        .collect()
}

pub(crate) fn scalar_out(v: f32) -> Value {
    Value::F32(Tensor::scalar(v))
}

// ---------------------------------------------------------------------------
// artifact implementations
// ---------------------------------------------------------------------------

/// Shared train-step core: forward/backward at `eval_ws`, optional STE
/// gradient scaling, Adam applied to the `p_` background parameters.
fn train_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    ste: bool,
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = mlp_sig(spec, "p_w")?;
    let nl = sig.layers();
    let slots = Slots::new(spec, inputs);
    let (ws, bs) = dense_params(&slots, nl)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let t = slots.scalar("t")?;
    let lr = slots.scalar("lr")?;
    let gs = if ste { slots.scalar("gs")? } else { 0.0 };

    // STE: quantized copies occupy the weight slots of the forward pass
    let qws = if ste { q_slots(&slots, "w", nl)? } else { vec![None; nl] };
    let eval_ws: Vec<&[f32]> = ws
        .iter()
        .zip(qws.iter())
        .map(|(&w, q)| q.unwrap_or(w))
        .collect();

    let (acts, logits) = forward_collect(scratch, &sig, &eval_ws, &bs, x);
    let (loss, g) = softmax_xent_grad(&logits, y, sig.batch, sig.classes());
    let correct = correct_count(&logits, y, sig.batch, sig.classes());
    let (mut dws, mut dbs, _) = backward(scratch, &sig, &eval_ws, &acts, g, false);

    // Fig. 5 step 3: scale quantized-weight gradients by |centroid|
    if ste && gs > 0.5 {
        ste_scale_grads(&mut dws, &qws);
    }

    let mut grads = Vec::with_capacity(2 * nl);
    for i in 0..nl {
        grads.push((format!("w{i}"), std::mem::take(&mut dws[i])));
        grads.push((format!("b{i}"), std::mem::take(&mut dbs[i])));
    }
    let mut out: HashMap<String, Value> = HashMap::new();
    adam_emit(spec, &slots, &grads, t, lr, &mut out)?;
    out.insert("loss".into(), scalar_out(loss));
    out.insert("correct".into(), scalar_out(correct));
    emit(spec, out)
}

/// Epsilon-rule LRP through a dense ladder starting at activation `x`
/// (model.py `MlpGsc::lrp`): forward keeping every layer input AND
/// pre-activation (the epsilon rule needs both, so ReLU cannot fuse),
/// relevance init at the logits, per-layer `r_w<i>` staged into `out`.
/// With `input_relevance`, also returns the relevance at the ladder's
/// input — the CNN head hands it back to its conv stack. Shared by the
/// MLP and CNN LRP artifacts so the dense rule exists exactly once.
pub(crate) fn lrp_dense_ladder(
    scratch: &mut Workspace,
    sig: &MlpSig,
    ws: &[&[f32]],
    bs: &[&[f32]],
    x: &[f32],
    y: &[i32],
    eqw: f32,
    input_relevance: bool,
    out: &mut HashMap<String, Value>,
) -> Option<Vec<f32>> {
    let nl = sig.layers();
    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut zs: Vec<Vec<f32>> = Vec::with_capacity(nl);
    for i in 0..nl {
        let (din, dout) = (sig.dims[i], sig.dims[i + 1]);
        let z = dense_fwd(scratch, &acts[i], ws[i], bs[i], sig.batch, din, dout, false);
        if i + 1 < nl {
            let mut h = z.clone();
            relu_inplace(&mut h);
            acts.push(h);
        }
        zs.push(z);
    }
    let logits = &zs[nl - 1];
    let classes = sig.classes();
    // initial relevance: onehot · (1 or target-class score)
    let mut r = vec![0.0f32; sig.batch * classes];
    for s in 0..sig.batch {
        let yc = y[s] as usize;
        let score = logits[s * classes + yc];
        r[s * classes + yc] = if eqw > 0.5 { 1.0 } else { score };
    }
    for i in (0..nl).rev() {
        let (din, dout) = (sig.dims[i], sig.dims[i + 1]);
        let a = &acts[i];
        let z = &zs[i];
        let s: Vec<f32> = r.iter().zip(z.iter()).map(|(&rv, &zv)| rv / stabilize(zv)).collect();
        let rw = lrp_dense_rw_ws(scratch, a, &s, ws[i], sig.batch, din, dout);
        out.insert(
            format!("r_w{i}"),
            Value::F32(Tensor::new(vec![din, dout], rw)),
        );
        if i > 0 || input_relevance {
            // R_in = a ⊙ (s @ wᵀ), the ⊙ fused into the NT GEMM's store
            let mut rin = vec![0.0f32; sig.batch * din];
            linalg::gemm_nt(scratch, &s, ws[i], sig.batch, dout, din, Epilogue::Scale(a), &mut rin);
            if i > 0 {
                r = rin;
            } else {
                return Some(rin);
            }
        }
    }
    None
}

/// Composite epsilon-LRP over the dense ladder: per-weight relevances,
/// batch-aggregated, signed.
fn lrp_step(spec: &ArtifactSpec, inputs: &[Value], scratch: &mut Workspace) -> Result<Vec<Value>> {
    let sig = mlp_sig(spec, "p_w")?;
    let slots = Slots::new(spec, inputs);
    let (ws, bs) = dense_params(&slots, sig.layers())?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let eqw = slots.scalar("eqw")?;
    let mut out: HashMap<String, Value> = HashMap::new();
    lrp_dense_ladder(scratch, &sig, &ws, &bs, x, y, eqw, false, &mut out);
    emit(spec, out)
}

/// Dense eval ladder from activation `a0`: ReLU fused on hidden layers,
/// optional per-tensor activation fake-quant (the Fig. 1 probe); returns
/// the logits. Shared by the MLP and CNN eval artifacts.
pub(crate) fn eval_dense_ladder(
    scratch: &mut Workspace,
    sig: &MlpSig,
    ws: &[&[f32]],
    bs: &[&[f32]],
    a0: &[f32],
    actq_levels: Option<f32>,
) -> Vec<f32> {
    let nl = sig.layers();
    let mut a = a0.to_vec();
    for i in 0..nl {
        let hidden = i + 1 < nl;
        let mut z =
            dense_fwd(scratch, &a, ws[i], bs[i], sig.batch, sig.dims[i], sig.dims[i + 1], hidden);
        if hidden {
            if let Some(levels) = actq_levels {
                act_fake_quant(&mut z, levels);
            }
        }
        a = z;
    }
    a
}

/// Plain eval (optionally with fake-quantized activations for the Fig. 1
/// sensitivity probe when the artifact carries an `abits` slot).
fn eval_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    actq: bool,
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = mlp_sig(spec, "p_w")?;
    let slots = Slots::new(spec, inputs);
    let (ws, bs) = dense_params(&slots, sig.layers())?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let levels = if actq { Some(2.0f32.powf(slots.scalar("abits")?)) } else { None };

    let a = eval_dense_ladder(scratch, &sig, &ws, &bs, x, levels);
    let loss = softmax_xent_loss(&a, y, sig.batch, sig.classes());
    let correct = correct_count(&a, y, sig.batch, sig.classes());
    let mut out = HashMap::new();
    out.insert("loss".to_string(), scalar_out(loss));
    out.insert("correct".to_string(), scalar_out(correct));
    emit(spec, out)
}

/// Deployment-form gather eval: int32 centroid indices + per-layer
/// codebook through `qdense_gather` (model.py `eval_gather_mlp`).
fn eval_gather_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = mlp_sig(spec, "idx_w")?;
    let nl = sig.layers();
    let slots = Slots::new(spec, inputs);
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;

    let mut a = x.to_vec();
    for i in 0..nl {
        let idx = slots.i32(&format!("idx_w{i}"))?;
        let cb = slots.f32(&format!("cb_w{i}"))?;
        let bias = slots.f32(&format!("p_b{i}"))?;
        let z = qdense_gather_ws(
            scratch,
            &a,
            idx,
            cb,
            bias,
            sig.batch,
            sig.dims[i],
            sig.dims[i + 1],
            i + 1 < nl,
        )
        .with_context(|| format!("artifact {}: layer {i}", spec.name))?;
        a = z;
    }
    let loss = softmax_xent_loss(&a, y, sig.batch, sig.classes());
    let correct = correct_count(&a, y, sig.batch, sig.classes());
    let mut out = HashMap::new();
    out.insert("loss".to_string(), scalar_out(loss));
    out.insert("correct".to_string(), scalar_out(correct));
    emit(spec, out)
}

/// Two-phase ECQ^x assignment over one padded bucket
/// (`python/compile/kernels/ecqx_assign.py::assign_full` semantics via
/// [`crate::quant::assign_raw`]).
fn assign_step(spec: &ArtifactSpec, inputs: &[Value]) -> Result<Vec<Value>> {
    let slots = Slots::new(spec, inputs);
    let w = slots.f32("w")?;
    let r = slots.f32("r")?;
    let mask = slots.f32("mask")?;
    let cen = slots.f32("centroids")?;
    let cv = slots.f32("cvalid")?;
    let lam = slots.scalar("lam")?;
    let a = assign_raw(w, r, mask, cen, cv, lam);
    let n = w.len();
    let mut out = HashMap::new();
    out.insert("idx".to_string(), Value::I32(TensorI32::new(vec![n], a.idx)));
    out.insert("qw".to_string(), Value::F32(Tensor::new(vec![n], a.qw)));
    out.insert(
        "counts".to_string(),
        Value::F32(Tensor::new(vec![cen.len()], a.counts)),
    );
    emit(spec, out)
}

// ---------------------------------------------------------------------------
// the backend
// ---------------------------------------------------------------------------

/// Artifact kinds the host backend can execute.
enum Kind {
    FpTrain,
    SteTrain,
    Lrp,
    Eval,
    EvalActq,
    EvalGather,
    Assign,
}

fn classify(name: &str) -> Result<Kind> {
    if name.starts_with("assign_") {
        Ok(Kind::Assign)
    } else if name.ends_with("_fp_train") {
        Ok(Kind::FpTrain)
    } else if name.ends_with("_ste_train") {
        Ok(Kind::SteTrain)
    } else if name.ends_with("_lrp") {
        Ok(Kind::Lrp)
    } else if name.ends_with("_eval_actq") {
        Ok(Kind::EvalActq)
    } else if name.ends_with("_eval_q") {
        Ok(Kind::EvalGather)
    } else if name.ends_with("_eval") {
        Ok(Kind::Eval)
    } else {
        bail!("host backend: unknown artifact kind {name}")
    }
}

/// True when the artifact's signature carries a conv ladder (executed by
/// [`super::host_cnn`] instead of the dense-MLP paths here).
fn is_cnn(spec: &ArtifactSpec) -> bool {
    spec.inputs.iter().any(|s| s.name == "p_c0" || s.name == "idx_c0")
}

/// The pure-rust host backend (stateless; `Send + Sync` trivially).
#[derive(Default)]
pub struct HostBackend;

impl HostBackend {
    /// Construct the host backend.
    pub fn new() -> HostBackend {
        HostBackend
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    /// Validate an artifact is host-executable (dense MLP or conv-ladder
    /// CNN signature, or an assign bucket) without running it — the host
    /// analogue of a compile.
    fn prepare(&self, spec: &ArtifactSpec) -> Result<()> {
        match classify(&spec.name)? {
            Kind::Assign => {
                for slot in ["w", "r", "mask", "centroids", "cvalid", "lam"] {
                    if !spec.inputs.iter().any(|s| s.name == slot) {
                        bail!("artifact {}: missing assign input {slot}", spec.name);
                    }
                }
                Ok(())
            }
            Kind::EvalGather if is_cnn(spec) => {
                super::host_cnn::cnn_sig(spec, "idx_c", "idx_w").map(|_| ())
            }
            Kind::EvalGather => mlp_sig(spec, "idx_w").map(|_| ()),
            _ if is_cnn(spec) => super::host_cnn::cnn_sig(spec, "p_c", "p_w").map(|_| ()),
            _ => mlp_sig(spec, "p_w").map(|_| ()),
        }
    }

    fn execute(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Value],
        scratch: &mut Workspace,
    ) -> Result<Vec<Value>> {
        use super::host_cnn;
        let cnn = is_cnn(spec);
        match classify(&spec.name)? {
            Kind::FpTrain if cnn => host_cnn::train_step(spec, inputs, false, scratch),
            Kind::SteTrain if cnn => host_cnn::train_step(spec, inputs, true, scratch),
            Kind::Lrp if cnn => host_cnn::lrp_step(spec, inputs, scratch),
            Kind::Eval if cnn => host_cnn::eval_step(spec, inputs, false, scratch),
            Kind::EvalActq if cnn => host_cnn::eval_step(spec, inputs, true, scratch),
            Kind::EvalGather if cnn => host_cnn::eval_gather_step(spec, inputs, scratch),
            Kind::FpTrain => train_step(spec, inputs, false, scratch),
            Kind::SteTrain => train_step(spec, inputs, true, scratch),
            Kind::Lrp => lrp_step(spec, inputs, scratch),
            Kind::Eval => eval_step(spec, inputs, false, scratch),
            Kind::EvalActq => eval_step(spec, inputs, true, scratch),
            Kind::EvalGather => eval_gather_step(spec, inputs, scratch),
            Kind::Assign => assign_step(spec, inputs),
        }
    }
}

/// Default host manifest: the paper's MLP_GSC ladder, the CIFAR-shaped
/// plain CNN, the pooled VGG-slim ladders (with and without BatchNorm)
/// and the residual ResNet-VOC ladder, plus the shared assign buckets
/// (the host twin of `python -m compile.aot` for the host-executable
/// models — every name `exp::model_exp` accepts must be servable here;
/// `tests/integration_runtime.rs` holds that contract).
pub fn default_manifest() -> Manifest {
    Manifest::synthetic_mlp("mlp_gsc", &Manifest::MLP_GSC_DIMS, 128)
        .merge(Manifest::synthetic_cnn(
            "cnn_cifar",
            (32, 32),
            3,
            &Manifest::CNN_CIFAR_CONVS,
            &Manifest::CNN_CIFAR_FC,
            32,
        ))
        .merge(Manifest::synthetic_vgg("vgg_cifar", false, 32))
        .merge(Manifest::synthetic_vgg_bn("vgg_cifar_bn", 32))
        .merge(Manifest::synthetic_resnet("resnet_voc", 32))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocked_kernels_match_retained_naive_references() {
        // the re-exported naive kernels are the oracle for the blocked
        // qdense path (the full property suite lives in
        // tests/linalg_gemm_props.rs)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 2, 3, 2), vec![4.0, 5.0, 10.0, 11.0]);
        let bias = [0.0, 0.0];
        assert_eq!(qdense(&a, &b, &bias, 2, 3, 2), matmul(&a, &b, 2, 3, 2));
        let rw = lrp_dense_rw(&a, &b, &b, 2, 3, 2);
        let mut want = matmul_tn(&a, &b, 2, 3, 2);
        for (r, &wv) in want.iter_mut().zip(&b) {
            *r *= wv;
        }
        assert_eq!(rw, want);
    }

    #[test]
    fn qdense_adds_bias_and_gather_matches_dense() {
        let a = [1.0, 1.0];
        let w = [0.5, -0.5, 0.25, 0.25];
        let bias = [1.0, 2.0];
        let z = qdense(&a, &w, &bias, 1, 2, 2);
        assert_eq!(z, vec![1.75, 1.75]);
        let cb = [0.0, 0.5, -0.5, 0.25];
        let idx = [1, 2, 3, 3];
        let zg = qdense_gather(&a, &idx, &cb, &bias, 1, 2, 2).unwrap();
        assert_eq!(zg, vec![1.75, 1.75]);
    }

    #[test]
    fn qdense_gather_rejects_empty_codebook() {
        // a corrupt container could carry an empty codebook; the host
        // path must error, not underflow `len() - 1` and panic
        let err = qdense_gather(&[1.0], &[0], &[], &[0.0], 1, 1, 1).unwrap_err();
        assert!(format!("{err:?}").contains("empty codebook"));
    }

    #[test]
    fn softmax_grad_sums_to_zero_and_loss_positive() {
        let logits = [1.0, -1.0, 0.5, 0.2, 0.2, 0.2];
        let y = [0, 2];
        let (loss, g) = softmax_xent_grad(&logits, &y, 2, 3);
        assert!(loss > 0.0);
        for row in g.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6, "grad rows sum to 0, got {s}");
        }
        assert_eq!(correct_count(&logits, &y, 2, 3), 1.0);
    }

    #[test]
    fn round_ties_even_matches_jnp() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(1.4), 1.0);
        assert_eq!(round_ties_even(1.6), 2.0);
    }

    #[test]
    fn classify_orders_eval_suffixes() {
        assert!(matches!(classify("m_eval_q").unwrap(), Kind::EvalGather));
        assert!(matches!(classify("m_eval_actq").unwrap(), Kind::EvalActq));
        assert!(matches!(classify("m_eval").unwrap(), Kind::Eval));
        assert!(matches!(classify("assign_1024").unwrap(), Kind::Assign));
        assert!(classify("m_unknown").is_err());
    }

    #[test]
    fn adam_identity_at_zero_lr() {
        let mut p = vec![1.0f32, -2.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adam_update(&mut p, &mut m, &mut v, &[0.3, -0.7], 1.0, 0.0);
        assert_eq!(p, vec![1.0, -2.0]);
        assert!(m[0] != 0.0 && v[0] != 0.0, "moments still accumulate");
    }
}
