//! Model state: full-precision parameters, Adam moments, quantized copy,
//! codebooks — the "background model" bookkeeping of the ECQ^x loop
//! (Fig. 5), plus binary checkpointing.

pub mod checkpoint;

use std::collections::BTreeMap;

use crate::quant::Codebook;
use crate::runtime::{Init, ModelSpec};
use crate::tensor::{Tensor, TensorI32};
use crate::util::Rng;

/// Per-quantized-layer quantization state.
#[derive(Clone, Debug)]
pub struct QLayer {
    /// dequantized weights (what the forward pass sees)
    pub qw: Tensor,
    /// centroid slot indices
    pub idx: TensorI32,
    /// codebook used for the current assignment
    pub codebook: Codebook,
}

/// Full state of one model under (pre-)training / QAT.
pub struct ModelState {
    pub spec: ModelSpec,
    /// full-precision background parameters (Fig. 5 step 4-5)
    pub params: BTreeMap<String, Tensor>,
    /// Adam first/second moments
    pub m: BTreeMap<String, Tensor>,
    pub v: BTreeMap<String, Tensor>,
    /// Adam step count
    pub t: u64,
    /// quantized copies of the quantize=1 parameters
    pub qlayers: BTreeMap<String, QLayer>,
}

impl ModelState {
    /// Initialize from the manifest spec with He/zeros/ones init.
    pub fn init(spec: &ModelSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut params = BTreeMap::new();
        let mut m = BTreeMap::new();
        let mut v = BTreeMap::new();
        for (li, p) in spec.params.iter().enumerate() {
            let mut lrng = rng.fork(li as u64);
            let t = match p.init {
                Init::Zeros => Tensor::zeros(&p.shape),
                Init::Ones => Tensor::ones(&p.shape),
                Init::HeIn => {
                    let fan_in: usize =
                        p.shape[..p.shape.len() - 1].iter().product::<usize>().max(1);
                    let std = (2.0 / fan_in as f32).sqrt();
                    let data =
                        (0..p.numel()).map(|_| lrng.normal_f32(0.0, std)).collect();
                    Tensor::new(p.shape.clone(), data)
                }
            };
            m.insert(p.name.clone(), Tensor::zeros(&p.shape));
            v.insert(p.name.clone(), Tensor::zeros(&p.shape));
            params.insert(p.name.clone(), t);
        }
        ModelState { spec: spec.clone(), params, m, v, t: 0, qlayers: BTreeMap::new() }
    }

    /// Names of quantized parameters, in spec order.
    pub fn qnames(&self) -> Vec<String> {
        self.spec
            .params
            .iter()
            .filter(|p| p.quantize)
            .map(|p| p.name.clone())
            .collect()
    }

    /// Names of all parameters, in spec order.
    pub fn pnames(&self) -> Vec<String> {
        self.spec.params.iter().map(|p| p.name.clone()).collect()
    }

    /// Overall sparsity across the quantized layers.
    pub fn quantized_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for ql in self.qlayers.values() {
            zeros += ql.idx.data.iter().filter(|&&i| i == 0).count();
            total += ql.idx.numel();
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }

    /// Effective parameters used in the quantized forward pass: quantized
    /// slots read from `qlayers`, the rest from the FP store.
    pub fn quantized_param(&self, name: &str) -> &Tensor {
        if let Some(ql) = self.qlayers.get(name) {
            &ql.qw
        } else {
            &self.params[name]
        }
    }

    /// Full-precision model size in bytes (the CR denominator).
    pub fn fp32_bytes(&self) -> usize {
        self.spec.total_params() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Init, ParamSpec};

    fn toy_spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            batch: 4,
            classes: 2,
            input_dim: 8,
            params: vec![
                ParamSpec {
                    name: "w0".into(),
                    shape: vec![8, 2],
                    init: Init::HeIn,
                    quantize: true,
                },
                ParamSpec {
                    name: "b0".into(),
                    shape: vec![2],
                    init: Init::Zeros,
                    quantize: false,
                },
                ParamSpec {
                    name: "g0".into(),
                    shape: vec![2],
                    init: Init::Ones,
                    quantize: false,
                },
            ],
        }
    }

    #[test]
    fn init_kinds() {
        let st = ModelState::init(&toy_spec(), 1);
        assert!(st.params["b0"].data.iter().all(|&x| x == 0.0));
        assert!(st.params["g0"].data.iter().all(|&x| x == 1.0));
        let w = &st.params["w0"];
        assert!(w.data.iter().any(|&x| x != 0.0));
        // He std ~ sqrt(2/8) = 0.5
        let std = crate::util::stats::std_dev(&w.data);
        assert!(std > 0.2 && std < 0.9, "std={std}");
        assert_eq!(st.qnames(), vec!["w0".to_string()]);
        assert_eq!(st.fp32_bytes(), (16 + 2 + 2) * 4);
    }

    #[test]
    fn init_deterministic() {
        let a = ModelState::init(&toy_spec(), 7);
        let b = ModelState::init(&toy_spec(), 7);
        assert_eq!(a.params["w0"].data, b.params["w0"].data);
        let c = ModelState::init(&toy_spec(), 8);
        assert_ne!(a.params["w0"].data, c.params["w0"].data);
    }

    #[test]
    fn quantized_param_prefers_qlayer() {
        let mut st = ModelState::init(&toy_spec(), 1);
        assert_eq!(
            st.quantized_param("w0").data,
            st.params["w0"].data
        );
        let cb = Codebook::symmetric(2, 0.1);
        st.qlayers.insert(
            "w0".into(),
            QLayer {
                qw: Tensor::zeros(&[8, 2]),
                idx: TensorI32::zeros(&[8, 2]),
                codebook: cb,
            },
        );
        assert!(st.quantized_param("w0").data.iter().all(|&x| x == 0.0));
        assert_eq!(st.quantized_sparsity(), 1.0);
    }
}
