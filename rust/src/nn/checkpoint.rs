//! Binary checkpoints: FP32 snapshots (pre-trained baselines) and the
//! `.ecqx` compressed-model container (centroid metadata + CABAC streams),
//! the deployable artifact whose on-disk size backs Table 1 / Figs. 9-10.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ModelState;
use crate::codec;
use crate::quant::Codebook;
use crate::tensor::{Tensor, TensorI32};

const FP_MAGIC: &[u8; 8] = b"ECQXFP32";
const Q_MAGIC: &[u8; 8] = b"ECQXQNT1";

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > 1 << 20 {
        bail!("string too long");
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

const MAX_RANK: usize = 8;

/// Read a shape header, validating rank and element count against the
/// same ceiling as the codec ([`codec::MAX_DECODE_ELEMS`]) so a corrupt
/// header cannot drive an unbounded allocation downstream.
fn read_shape(r: &mut impl Read) -> Result<(Vec<usize>, usize)> {
    let rank = read_u32(r)? as usize;
    if rank > MAX_RANK {
        bail!("tensor rank {rank} exceeds {MAX_RANK}");
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u32(r)? as usize);
    }
    let mut numel = 1usize;
    for &d in &shape {
        numel = match numel.checked_mul(d) {
            Some(n) if n <= codec::MAX_DECODE_ELEMS => n,
            _ => bail!("tensor numel exceeds decode ceiling (shape {shape:?})"),
        };
    }
    Ok((shape, numel))
}

/// Read `numel` little-endian f32s. Capacity grows with bytes actually
/// read, so a header claiming more elements than the file holds fails at
/// the first short read instead of pre-allocating the claimed size.
fn read_f32_vec(r: &mut impl Read, numel: usize) -> Result<Vec<f32>> {
    let mut data = Vec::with_capacity(numel.min(1 << 16));
    for _ in 0..numel {
        data.push(read_f32(r)?);
    }
    Ok(data)
}

/// Save the FP parameter store (pre-trained baseline snapshot).
/// Written atomically (tmp + rename): a crash mid-save leaves any
/// previous snapshot intact, never a truncated one.
pub fn save_fp(path: &Path, params: &BTreeMap<String, Tensor>) -> Result<()> {
    crate::util::fsx::atomic_write_with(path, |w| {
        w.write_all(FP_MAGIC)?;
        write_u32(w, params.len() as u32)?;
        for (name, t) in params {
            write_str(w, name)?;
            write_u32(w, t.shape.len() as u32)?;
            for &d in &t.shape {
                write_u32(w, d as u32)?;
            }
            for &v in &t.data {
                write_f32(w, v)?;
            }
        }
        Ok(())
    })
}

/// Load an FP snapshot.
pub fn load_fp(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != FP_MAGIC {
        bail!("not an ECQX FP checkpoint");
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name = read_str(&mut r)?;
        let (shape, numel) = read_shape(&mut r)?;
        let data = read_f32_vec(&mut r, numel)
            .with_context(|| format!("read FP tensor {name}"))?;
        out.insert(name, Tensor::new(shape, data));
    }
    Ok(out)
}

/// One quantized layer in the `.ecqx` container.
pub struct QuantizedLayer {
    pub name: String,
    pub enc: codec::EncodedTensor,
}

/// Serialize a quantized model: CABAC-coded integer levels per quantized
/// layer + FP32 payload for the unquantized parameters (biases, BN).
/// Returns the container size in bytes.
pub fn save_quantized(path: &Path, state: &ModelState) -> Result<usize> {
    save_quantized_jobs(path, state, 1)
}

/// [`save_quantized`] with the per-layer entropy coding fanned out over
/// `jobs` workers (flat (layer, chunk) work units via
/// [`codec::encode_tensors_jobs`]). The written container is bitwise
/// identical at any job count, and lands atomically (tmp + rename): the
/// destination path never holds a truncated container.
pub fn save_quantized_jobs(path: &Path, state: &ModelState, jobs: usize) -> Result<usize> {
    crate::util::fsx::atomic_write_with(path, |w| {
        w.write_all(Q_MAGIC)?;
        write_str(w, &state.spec.name)?;
        let qnames = state.qnames();
        write_u32(w, qnames.len() as u32)?;
        let inputs = qnames
            .iter()
            .map(|name| {
                let ql = state
                    .qlayers
                    .get(name)
                    .with_context(|| format!("layer {name} not quantized"))?;
                Ok((&ql.idx, &ql.codebook))
            })
            .collect::<Result<Vec<_>>>()?;
        let encs = codec::encode_tensors_jobs(&inputs, jobs);
        for (name, enc) in qnames.iter().zip(&encs) {
            write_str(w, name)?;
            write_u32(w, enc.bits)?;
            write_f32(w, enc.step)?;
            write_u32(w, enc.shape.len() as u32)?;
            for &d in &enc.shape {
                write_u32(w, d as u32)?;
            }
            write_u32(w, enc.payload.len() as u32)?;
            w.write_all(&enc.payload)?;
        }
        // unquantized params raw fp32
        let other: Vec<&String> = state
            .params
            .keys()
            .filter(|k| !qnames.contains(k))
            .collect();
        write_u32(w, other.len() as u32)?;
        for name in other {
            let t = &state.params[name];
            write_str(w, name)?;
            write_u32(w, t.shape.len() as u32)?;
            for &d in &t.shape {
                write_u32(w, d as u32)?;
            }
            for &v in &t.data {
                write_f32(w, v)?;
            }
        }
        Ok(())
    })?;
    Ok(std::fs::metadata(path)?.len() as usize)
}

/// A loaded `.ecqx` container.
pub struct QuantizedModel {
    pub model: String,
    /// per-layer (indices, codebook)
    pub layers: BTreeMap<String, (TensorI32, Codebook)>,
    pub other: BTreeMap<String, Tensor>,
}

/// Load + decode a `.ecqx` container (lossless inverse of save).
pub fn load_quantized(path: &Path) -> Result<QuantizedModel> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != Q_MAGIC {
        bail!("not an ECQX quantized container");
    }
    let model = read_str(&mut r)?;
    let nq = read_u32(&mut r)? as usize;
    let mut layers = BTreeMap::new();
    for _ in 0..nq {
        let name = read_str(&mut r)?;
        let bits = read_u32(&mut r)?;
        // Codebook::symmetric asserts this range — reject corrupt headers
        // here so a hostile container errors instead of panicking
        if !(2..=5).contains(&bits) {
            bail!("layer {name}: bit width {bits} outside 2..=5");
        }
        let step = read_f32(&mut r)?;
        let (shape, _numel) = read_shape(&mut r)?;
        let plen = read_u32(&mut r)? as u64;
        // take()-bounded read: allocation grows with bytes actually
        // present, so a corrupt plen cannot demand 4 GiB up front
        let mut payload = Vec::new();
        let got = r.by_ref().take(plen).read_to_end(&mut payload)? as u64;
        if got != plen {
            bail!("layer {name}: payload truncated ({got} of {plen} bytes)");
        }
        let enc = codec::EncodedTensor { shape, step, bits, payload };
        let idx = codec::decode_tensor(&enc)
            .with_context(|| format!("decode layer {name}"))?;
        layers.insert(name, (idx, Codebook::symmetric(bits, step)));
    }
    let no = read_u32(&mut r)? as usize;
    let mut other = BTreeMap::new();
    for _ in 0..no {
        let name = read_str(&mut r)?;
        let (shape, numel) = read_shape(&mut r)?;
        let data = read_f32_vec(&mut r, numel)
            .with_context(|| format!("read FP tensor {name}"))?;
        other.insert(name, Tensor::new(shape, data));
    }
    Ok(QuantizedModel { model, layers, other })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::QLayer;
    use crate::runtime::{Init, ModelSpec, ParamSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ecqx-ckpt-{}-{name}", std::process::id()))
    }

    fn toy_state() -> ModelState {
        let spec = ModelSpec {
            name: "toy".into(),
            batch: 2,
            classes: 2,
            input_dim: 4,
            params: vec![
                ParamSpec {
                    name: "w0".into(),
                    shape: vec![4, 2],
                    init: Init::HeIn,
                    quantize: true,
                },
                ParamSpec {
                    name: "b0".into(),
                    shape: vec![2],
                    init: Init::Zeros,
                    quantize: false,
                },
            ],
        };
        let mut st = ModelState::init(&spec, 3);
        let cb = Codebook::symmetric(4, 0.1);
        let idx = TensorI32::new(vec![4, 2], vec![0, 1, 2, 0, 3, 0, 0, 5]);
        let qw = Tensor::new(
            vec![4, 2],
            idx.data.iter().map(|&i| cb.values[i as usize]).collect(),
        );
        st.qlayers.insert("w0".into(), QLayer { qw, idx, codebook: cb });
        st
    }

    #[test]
    fn fp_roundtrip() {
        let st = toy_state();
        let p = tmp("fp.bin");
        save_fp(&p, &st.params).unwrap();
        let loaded = load_fp(&p).unwrap();
        assert_eq!(loaded["w0"].data, st.params["w0"].data);
        assert_eq!(loaded["b0"].shape, vec![2]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn quantized_roundtrip() {
        let st = toy_state();
        let p = tmp("q.ecqx");
        let size = save_quantized(&p, &st).unwrap();
        assert!(size > 0);
        let qm = load_quantized(&p).unwrap();
        assert_eq!(qm.model, "toy");
        let (idx, cb) = &qm.layers["w0"];
        assert_eq!(idx.data, st.qlayers["w0"].idx.data);
        assert_eq!(cb.bits, 4);
        assert!((cb.step - 0.1).abs() < 1e-6);
        assert_eq!(qm.other["b0"].data, st.params["b0"].data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTAMAGIC123").unwrap();
        assert!(load_fp(&p).is_err());
        assert!(load_quantized(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn save_jobs_bitwise_identical() {
        let st = toy_state();
        let p1 = tmp("q-j1.ecqx");
        let p3 = tmp("q-j3.ecqx");
        save_quantized_jobs(&p1, &st, 1).unwrap();
        save_quantized_jobs(&p3, &st, 3).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p3).unwrap());
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p3).ok();
    }

    #[test]
    fn truncated_container_is_error_not_panic() {
        let st = toy_state();
        let p = tmp("q-trunc.ecqx");
        save_quantized(&p, &st).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        for cut in [bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&p, &bytes[..cut]).unwrap();
            assert!(load_quantized(&p).is_err(), "cut at {cut} should fail cleanly");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn absurd_header_claims_rejected() {
        // hand-built container claiming a ~4 GiB payload for an 8-element
        // layer: must error at the framing check, not allocate the claim
        let p = tmp("q-absurd.ecqx");
        let mut b = Vec::new();
        b.extend_from_slice(Q_MAGIC);
        b.extend_from_slice(&3u32.to_le_bytes()); // model name len
        b.extend_from_slice(b"toy");
        b.extend_from_slice(&1u32.to_le_bytes()); // one quantized layer
        b.extend_from_slice(&2u32.to_le_bytes()); // name len
        b.extend_from_slice(b"w0");
        b.extend_from_slice(&4u32.to_le_bytes()); // bits
        b.extend_from_slice(&0.1f32.to_le_bytes()); // step
        b.extend_from_slice(&1u32.to_le_bytes()); // rank
        b.extend_from_slice(&8u32.to_le_bytes()); // dim
        b.extend_from_slice(&u32::MAX.to_le_bytes()); // plen claim
        b.extend_from_slice(&[0u8; 16]); // ...but only 16 bytes present
        std::fs::write(&p, &b).unwrap();
        let err = load_quantized(&p).unwrap_err();
        assert!(format!("{err:?}").contains("truncated"), "{err:?}");

        // and an FP tensor whose shape overflows the decode ceiling
        let mut b = Vec::new();
        b.extend_from_slice(FP_MAGIC);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(b"w");
        b.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        let err = load_fp(&p).unwrap_err();
        assert!(format!("{err:?}").contains("ceiling"), "{err:?}");
        std::fs::remove_file(&p).ok();
    }
}
