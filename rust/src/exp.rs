//! Shared experiment setup used by the CLI, examples and benches:
//! engine construction, per-model datasets, and cached pre-trained
//! baselines (so every figure bench starts from the same snapshot).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::binder::ParamSource;
use crate::coordinator::trainer::{evaluate, Pretrainer};
use crate::data::gsc::GscDataset;
use crate::data::images::{CifarDataset, VocDataset};
use crate::data::{DataLoader, Dataset};
use crate::nn::checkpoint;
use crate::nn::ModelState;
use crate::runtime::Engine;

/// Artifact directory: $ECQX_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ECQX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Which execution backend to construct (CLI `--backend`, env
/// `ECQX_BACKEND`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// PJRT when artifacts + real bindings are available, host otherwise.
    Auto,
    /// Pure-rust host reference backend (no artifacts, no PJRT).
    Host,
    /// PJRT over `artifacts/` (errors when unavailable).
    Pjrt,
}

impl std::str::FromStr for BackendChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(BackendChoice::Auto),
            "host" => Ok(BackendChoice::Host),
            "pjrt" => Ok(BackendChoice::Pjrt),
            other => anyhow::bail!("unknown backend {other} (use auto|host|pjrt)"),
        }
    }
}

/// Construct the engine for an explicit backend choice. `Auto` picks PJRT
/// only when `artifacts/manifest.txt` exists *and* the real bindings are
/// linked (`backend_is_stub() == false`); otherwise it falls back to the
/// host reference backend, so every CLI/bench/example path runs offline.
pub fn engine_with(choice: BackendChoice) -> Result<Engine> {
    let dir = artifacts_dir();
    match choice {
        BackendChoice::Host => Ok(Engine::host()),
        BackendChoice::Pjrt => Engine::new(&dir).with_context(|| {
            format!(
                "loading artifacts from {} (run `make artifacts` first)",
                dir.display()
            )
        }),
        BackendChoice::Auto => {
            if dir.join("manifest.txt").exists() && !crate::runtime::backend_is_stub() {
                engine_with(BackendChoice::Pjrt)
            } else {
                Ok(Engine::host())
            }
        }
    }
}

/// Construct the default engine: `$ECQX_BACKEND` (auto|host|pjrt) or the
/// auto fallback chain.
pub fn engine() -> Result<Engine> {
    let choice = match std::env::var("ECQX_BACKEND") {
        Ok(v) => v.parse()?,
        Err(_) => BackendChoice::Auto,
    };
    engine_with(choice)
}

/// Experiment scale: paper-like vs CPU-budget (bench default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// small grids/epochs for CPU wall-clock (default for benches)
    Bench,
    /// closer to the paper's 20-epoch runs (CLI --paper-scale)
    Paper,
}

/// Per-model experiment descriptor.
#[derive(Clone, Copy, Debug)]
pub struct ModelExp {
    pub name: &'static str,
    pub train_n: usize,
    pub val_n: usize,
    pub pretrain_epochs: usize,
    pub pretrain_lr: f32,
    pub qat_epochs: usize,
    pub qat_lr: f32,
}

pub const MLP_GSC: ModelExp = ModelExp {
    name: "mlp_gsc",
    train_n: 8192,
    val_n: 2048,
    pretrain_epochs: 12,
    pretrain_lr: 1e-3,
    qat_epochs: 3,
    qat_lr: 2e-4,
};

/// The host-executable CNN workload: CIFAR-shaped conv ladder + dense
/// head (`Manifest::synthetic_cnn`), trained on the synthetic CIFAR set.
pub const CNN_CIFAR: ModelExp = ModelExp {
    name: "cnn_cifar",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 6,
    pretrain_lr: 1e-3,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

pub const VGG_CIFAR: ModelExp = ModelExp {
    name: "vgg_cifar",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 10,
    pretrain_lr: 5e-4,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

pub const VGG_CIFAR_BN: ModelExp = ModelExp {
    name: "vgg_cifar_bn",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 10,
    pretrain_lr: 5e-4,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

pub const RESNET_VOC: ModelExp = ModelExp {
    name: "resnet_voc",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 10,
    pretrain_lr: 1e-3,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

/// Every experiment this binary accepts — the accept/refuse contract:
/// each of these names must run end-to-end on the host backend
/// (`tests/integration_runtime.rs` drives a one-step trial per entry),
/// and [`model_exp`] must refuse everything else.
pub const ALL_MODELS: [ModelExp; 5] = [MLP_GSC, CNN_CIFAR, VGG_CIFAR, VGG_CIFAR_BN, RESNET_VOC];

pub fn model_exp(name: &str) -> Result<ModelExp> {
    for m in ALL_MODELS {
        if m.name == name {
            return Ok(m);
        }
    }
    anyhow::bail!("unknown model {name}")
}

/// Boxed dataset pair (train, val) for a model.
pub fn datasets(exp: &ModelExp, seed: u64) -> (Box<dyn Dataset>, Box<dyn Dataset>) {
    match exp.name {
        "mlp_gsc" => (
            Box::new(GscDataset::new(exp.train_n, seed, true)),
            Box::new(GscDataset::new(exp.val_n, seed, false)),
        ),
        "cnn_cifar" | "vgg_cifar" | "vgg_cifar_bn" => (
            Box::new(CifarDataset::new(exp.train_n, seed, true)),
            Box::new(CifarDataset::new(exp.val_n, seed, false)),
        ),
        "resnet_voc" => (
            Box::new(VocDataset::new(exp.train_n, seed, true)),
            Box::new(VocDataset::new(exp.val_n, seed, false)),
        ),
        other => panic!("unknown model {other}"),
    }
}

impl Dataset for Box<dyn Dataset> {
    fn len(&self) -> usize {
        (**self).len()
    }
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn classes(&self) -> usize {
        (**self).classes()
    }
    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        (**self).sample_into(i, out)
    }
}

/// Pre-trained FP snapshot + its baseline validation accuracy.
pub struct Pretrained {
    pub state: ModelState,
    pub baseline_acc: f64,
}

/// Get (or train + cache) the pre-trained FP baseline of a model.
///
/// Cached under `artifacts/pretrained_<model>_<backend>.bin` (+ `.meta`
/// with the baseline accuracy), keyed on the pretraining configuration.
/// The backend is part of the file name — host- and PJRT-trained
/// baselines differ numerically, and alternating backends must not
/// clobber each other's cache.
pub fn pretrained(engine: &Engine, exp: &ModelExp, seed: u64) -> Result<Pretrained> {
    let spec = engine.manifest.model(exp.name)?.clone();
    let backend = engine.backend_name();
    let ckpt = artifacts_dir().join(format!("pretrained_{}_{backend}.bin", exp.name));
    let meta = artifacts_dir().join(format!("pretrained_{}_{backend}.meta", exp.name));
    // NB: keyed on the pretraining config + backend, not the artifact
    // hash — kernel perf changes must not invalidate baselines (semantics
    // are covered by the artifact-vs-reference integration tests), but
    // host- and PJRT-trained baselines differ numerically and must not
    // poison each other's cache.
    let tag = format!(
        "seed={seed} epochs={} lr={} train_n={} backend={}",
        exp.pretrain_epochs,
        exp.pretrain_lr,
        exp.train_n,
        engine.backend_name()
    );
    if ckpt.exists() && meta.exists() {
        let m = std::fs::read_to_string(&meta)?;
        let mut lines = m.lines();
        if lines.next() == Some(tag.as_str()) {
            if let Some(acc) = lines.next().and_then(|l| l.parse::<f64>().ok()) {
                let params = checkpoint::load_fp(&ckpt)?;
                let mut state = ModelState::init(&spec, seed);
                state.params = params;
                return Ok(Pretrained { state, baseline_acc: acc });
            }
        }
    }
    println!(
        "[pretrain] no cached baseline for {} — training {} epochs ...",
        exp.name, exp.pretrain_epochs
    );
    let (train, val) = datasets(exp, seed);
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let mut state = ModelState::init(&spec, seed);
    let pre = Pretrainer { lr: exp.pretrain_lr, ..Default::default() };
    pre.run(engine, &mut state, &train_dl, exp.pretrain_epochs)?;
    let ev = evaluate(engine, &state, &val_dl, ParamSource::Fp)?;
    println!("[pretrain] {} baseline val acc = {:.4}", exp.name, ev.accuracy);
    // the host backend runs with no artifacts/ directory present — create
    // the cache location on demand
    std::fs::create_dir_all(artifacts_dir()).ok();
    // checkpoint first, meta second, both atomic: a crash between the two
    // leaves a stale/missing meta, which just re-trains — never a meta
    // that vouches for a half-written checkpoint
    checkpoint::save_fp(&ckpt, &state.params)?;
    crate::util::fsx::atomic_write(&meta, format!("{tag}\n{}\n", ev.accuracy).as_bytes())?;
    Ok(Pretrained { state, baseline_acc: ev.accuracy })
}

/// Default lambda grids per model/bits (bench scale).
pub fn lambda_grid(scale: Scale) -> Vec<f32> {
    match scale {
        Scale::Bench => vec![0.0, 0.02, 0.08, 0.25],
        Scale::Paper => vec![0.0, 0.005, 0.01, 0.02, 0.04, 0.08, 0.15, 0.25, 0.5],
    }
}
