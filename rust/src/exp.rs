//! Shared experiment setup used by the CLI, examples and benches:
//! engine construction, per-model datasets, and cached pre-trained
//! baselines (so every figure bench starts from the same snapshot).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::binder::ParamSource;
use crate::coordinator::trainer::{evaluate, Pretrainer};
use crate::data::gsc::GscDataset;
use crate::data::images::{CifarDataset, VocDataset};
use crate::data::{DataLoader, Dataset};
use crate::nn::checkpoint;
use crate::nn::ModelState;
use crate::runtime::Engine;

/// Artifact directory: $ECQX_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ECQX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Construct the PJRT engine over the artifact directory.
pub fn engine() -> Result<Engine> {
    let dir = artifacts_dir();
    Engine::new(&dir).with_context(|| {
        format!(
            "loading artifacts from {} (run `make artifacts` first)",
            dir.display()
        )
    })
}

/// Experiment scale: paper-like vs CPU-budget (bench default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// small grids/epochs for CPU wall-clock (default for benches)
    Bench,
    /// closer to the paper's 20-epoch runs (CLI --paper-scale)
    Paper,
}

/// Per-model experiment descriptor.
#[derive(Clone, Copy, Debug)]
pub struct ModelExp {
    pub name: &'static str,
    pub train_n: usize,
    pub val_n: usize,
    pub pretrain_epochs: usize,
    pub pretrain_lr: f32,
    pub qat_epochs: usize,
    pub qat_lr: f32,
}

pub const MLP_GSC: ModelExp = ModelExp {
    name: "mlp_gsc",
    train_n: 8192,
    val_n: 2048,
    pretrain_epochs: 12,
    pretrain_lr: 1e-3,
    qat_epochs: 3,
    qat_lr: 2e-4,
};

pub const VGG_CIFAR: ModelExp = ModelExp {
    name: "vgg_cifar",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 10,
    pretrain_lr: 5e-4,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

pub const VGG_CIFAR_BN: ModelExp = ModelExp {
    name: "vgg_cifar_bn",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 10,
    pretrain_lr: 5e-4,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

pub const RESNET_VOC: ModelExp = ModelExp {
    name: "resnet_voc",
    train_n: 2048,
    val_n: 512,
    pretrain_epochs: 10,
    pretrain_lr: 1e-3,
    qat_epochs: 2,
    qat_lr: 1e-4,
};

pub fn model_exp(name: &str) -> Result<ModelExp> {
    Ok(match name {
        "mlp_gsc" => MLP_GSC,
        "vgg_cifar" => VGG_CIFAR,
        "vgg_cifar_bn" => VGG_CIFAR_BN,
        "resnet_voc" => RESNET_VOC,
        other => anyhow::bail!("unknown model {other}"),
    })
}

/// Boxed dataset pair (train, val) for a model.
pub fn datasets(exp: &ModelExp, seed: u64) -> (Box<dyn Dataset>, Box<dyn Dataset>) {
    match exp.name {
        "mlp_gsc" => (
            Box::new(GscDataset::new(exp.train_n, seed, true)),
            Box::new(GscDataset::new(exp.val_n, seed, false)),
        ),
        "vgg_cifar" | "vgg_cifar_bn" => (
            Box::new(CifarDataset::new(exp.train_n, seed, true)),
            Box::new(CifarDataset::new(exp.val_n, seed, false)),
        ),
        "resnet_voc" => (
            Box::new(VocDataset::new(exp.train_n, seed, true)),
            Box::new(VocDataset::new(exp.val_n, seed, false)),
        ),
        other => panic!("unknown model {other}"),
    }
}

impl Dataset for Box<dyn Dataset> {
    fn len(&self) -> usize {
        (**self).len()
    }
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn classes(&self) -> usize {
        (**self).classes()
    }
    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        (**self).sample_into(i, out)
    }
}

/// Pre-trained FP snapshot + its baseline validation accuracy.
pub struct Pretrained {
    pub state: ModelState,
    pub baseline_acc: f64,
}

/// Get (or train + cache) the pre-trained FP baseline of a model.
///
/// Cached under `artifacts/pretrained_<model>.bin` (+ `.meta` with the
/// baseline accuracy), keyed on the pretraining configuration.
pub fn pretrained(engine: &Engine, exp: &ModelExp, seed: u64) -> Result<Pretrained> {
    let spec = engine.manifest.model(exp.name)?.clone();
    let ckpt = artifacts_dir().join(format!("pretrained_{}.bin", exp.name));
    let meta = artifacts_dir().join(format!("pretrained_{}.meta", exp.name));
    // NB: keyed on the pretraining config, not the artifact hash — kernel
    // perf changes must not invalidate baselines (semantics are covered by
    // the artifact-vs-reference integration tests).
    let tag = format!(
        "seed={seed} epochs={} lr={} train_n={}",
        exp.pretrain_epochs, exp.pretrain_lr, exp.train_n
    );
    if ckpt.exists() && meta.exists() {
        let m = std::fs::read_to_string(&meta)?;
        let mut lines = m.lines();
        if lines.next() == Some(tag.as_str()) {
            if let Some(acc) = lines.next().and_then(|l| l.parse::<f64>().ok()) {
                let params = checkpoint::load_fp(&ckpt)?;
                let mut state = ModelState::init(&spec, seed);
                state.params = params;
                return Ok(Pretrained { state, baseline_acc: acc });
            }
        }
    }
    println!(
        "[pretrain] no cached baseline for {} — training {} epochs ...",
        exp.name, exp.pretrain_epochs
    );
    let (train, val) = datasets(exp, seed);
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let mut state = ModelState::init(&spec, seed);
    let pre = Pretrainer { lr: exp.pretrain_lr, ..Default::default() };
    pre.run(engine, &mut state, &train_dl, exp.pretrain_epochs)?;
    let ev = evaluate(engine, &state, &val_dl, ParamSource::Fp)?;
    println!("[pretrain] {} baseline val acc = {:.4}", exp.name, ev.accuracy);
    checkpoint::save_fp(&ckpt, &state.params)?;
    std::fs::write(&meta, format!("{tag}\n{}\n", ev.accuracy))?;
    Ok(Pretrained { state, baseline_acc: ev.accuracy })
}

/// Default lambda grids per model/bits (bench scale).
pub fn lambda_grid(scale: Scale) -> Vec<f32> {
    match scale {
        Scale::Bench => vec![0.0, 0.02, 0.08, 0.25],
        Scale::Paper => vec![0.0, 0.005, 0.01, 0.02, 0.04, 0.08, 0.15, 0.25, 0.5],
    }
}
