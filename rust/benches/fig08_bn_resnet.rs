//! Fig. 8 — ECQ vs ECQ^x on the BatchNorm architectures: VGG with BN
//! modules (left) and ResNet (right), 4 bit. LRP keeps BN layers separate
//! (alpha-beta rule with beta = 1, no canonization merge).

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::Method;
use ecqx::exp;
use sweep_common::{run_trials, smoke_scaled, Trial};

fn main() -> anyhow::Result<()> {
    figure_header("Fig.8", "ECQ vs ECQx on BatchNorm architectures, 4 bit");
    let engine = exp::engine()?;
    let (vgg_bn, resnet) = (smoke_scaled(&exp::VGG_CIFAR_BN), smoke_scaled(&exp::RESNET_VOC));
    for method in [Method::Ecq, Method::Ecqx] {
        let trials = vec![Trial { method, bits: 4, lambda: 8.0, p: 0.15 }];
        run_trials(&engine, &vgg_bn, "fig8-vgg_bn", &trials, 1)?;
    }
    for method in [Method::Ecq, Method::Ecqx] {
        let trials = vec![Trial { method, bits: 4, lambda: 8.0, p: 0.15 }];
        run_trials(&engine, &resnet, "fig8-resnet", &trials, 1)?;
    }
    Ok(())
}
