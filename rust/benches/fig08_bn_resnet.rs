//! Fig. 8 — ECQ vs ECQ^x on the BatchNorm architectures: VGG with BN
//! modules (left) and ResNet (right), 4 bit. LRP keeps BN layers separate
//! (alpha-beta rule with beta = 1, no canonization merge).

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::Method;
use ecqx::exp;
use sweep_common::{run_trials, Trial};

fn main() -> anyhow::Result<()> {
    figure_header("Fig.8", "ECQ vs ECQx on BatchNorm architectures, 4 bit");
    let engine = exp::engine()?;
    for method in [Method::Ecq, Method::Ecqx] {
        let trials = vec![Trial { method, bits: 4, lambda: 8.0, p: 0.15 }];
        run_trials(&engine, &exp::VGG_CIFAR_BN, "fig8-vgg_bn", &trials, 1)?;
    }
    for method in [Method::Ecq, Method::Ecqx] {
        let trials = vec![Trial { method, bits: 4, lambda: 8.0, p: 0.15 }];
        run_trials(&engine, &exp::RESNET_VOC, "fig8-resnet", &trials, 1)?;
    }
    Ok(())
}
