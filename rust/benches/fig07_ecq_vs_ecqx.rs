//! Fig. 7 — ECQ vs ECQ^x, 4-bit quantization of MLP_GSC (left panel) and
//! VGG (right panel): accuracy-vs-sparsity working points over a lambda
//! grid. Expected shape: both methods hold accuracy at moderate sparsity;
//! in the high-sparsity regime ECQ degrades faster.

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::Method;
use ecqx::exp;
use sweep_common::{run_trials, Trial};

fn main() -> anyhow::Result<()> {
    figure_header("Fig.7", "ECQ vs ECQx, 4 bit: accuracy vs sparsity");
    let engine = exp::engine()?;
    let lambdas = [4.0f32, 10.0, 16.0];
    for method in [Method::Ecq, Method::Ecqx] {
        let trials: Vec<Trial> = lambdas
            .iter()
            .map(|&lambda| Trial { method, bits: 4, lambda, p: 0.15 })
            .collect();
        run_trials(&engine, &exp::MLP_GSC, "fig7-mlp_gsc", &trials, 1)?;
    }
    // right panel: VGG (one lambda per method at bench scale)
    for method in [Method::Ecq, Method::Ecqx] {
        let trials = vec![Trial { method, bits: 4, lambda: 8.0, p: 0.15 }];
        run_trials(&engine, &exp::VGG_CIFAR, "fig7-vgg", &trials, 1)?;
    }
    Ok(())
}
