//! Fig. 2 — non-uniform (k-means) quantization of one layer's weights:
//! the binned weight distribution with the 7 k-means centroids and their
//! assignment counts, vs the uniform grid for comparison.

use ecqx::bench::{bench, figure_header, series_row};
use ecqx::exp;
use ecqx::quant::kmeans::kmeans_1d;
use ecqx::quant::Codebook;
use ecqx::util::stats;

fn main() -> anyhow::Result<()> {
    figure_header("Fig.2", "k-means clustering of MLP_GSC layer-0 weights (K=7)");
    let engine = exp::engine()?;
    let pre = exp::pretrained(&engine, &exp::MLP_GSC, 17)?;
    let w = &pre.state.params["w0"].data;
    let wmax = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));

    // the green bars: binned weight distribution
    let hist = stats::histogram(w, -wmax, wmax, 31);
    series_row(
        "weight-hist",
        &[("bins", format!("{hist:?}")), ("wmax", format!("{wmax:.4}"))],
    );

    // the black bars: k-means centroids + their populations
    let km = kmeans_1d(w, 7, 60, 1);
    let mut pairs: Vec<(f32, usize)> =
        km.centroids.iter().cloned().zip(km.counts.iter().cloned()).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (c, n) in &pairs {
        series_row("kmeans", &[("centroid", format!("{c:.4}")), ("count", n.to_string())]);
    }
    series_row(
        "kmeans-fit",
        &[
            ("inertia", format!("{:.4}", km.inertia)),
            ("iterations", km.iterations.to_string()),
        ],
    );

    // uniform grid comparison: non-uniform must fit the distribution better
    let cb = Codebook::fit(w, 3); // 7 centroids
    let uniform_inertia: f64 = w
        .iter()
        .map(|&x| {
            cb.values
                .iter()
                .zip(cb.valid.iter())
                .filter(|(_, &v)| v > 0.5)
                .map(|(&c, _)| ((x - c) as f64).powi(2))
                .fold(f64::INFINITY, f64::min)
        })
        .sum();
    series_row(
        "uniform",
        &[
            ("inertia", format!("{uniform_inertia:.4}")),
            ("ratio", format!("{:.3}", uniform_inertia / km.inertia.max(1e-12))),
        ],
    );
    assert!(km.inertia <= uniform_inertia, "k-means must dominate uniform");

    bench("kmeans_1d K=7 on 184k weights", 1, 3, || kmeans_1d(w, 7, 60, 1));
    Ok(())
}
