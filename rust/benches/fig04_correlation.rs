//! Fig. 4 — weight relevance vs weight value correlation analysis.
//!
//! Collects LRP relevances over the validation set (equally-weighted
//! samples, R_n = 1 — the paper's Fig. 4 setting) through the
//! `mlp_gsc_lrp` artifact and reports, per layer, the Pearson correlation
//! `c` plus the marginal histograms of the paper's panels. The paper's
//! claim to verify: relevance and magnitude decorrelate, especially near
//! the input.

use ecqx::bench::{figure_header, series_row};
use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::lrp::analysis::{correlation_panel, small_weight_relevance_share};
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    figure_header("Fig.4", "relevance vs weight correlation (MLP_GSC, R_n = 1)");
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (_, val) = exp::datasets(&model, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);

    // aggregate |relevance| over the validation set
    let art = engine.manifest.artifact("mlp_gsc_lrp")?.clone();
    let mut acc: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut batches = 0;
    for batch in val_dl.epoch(0) {
        let sc = Scalars { eqw: 1.0, ..Default::default() };
        let inputs = bind_inputs(&art, &pre.state, ParamSource::Fp, Some(&batch), &sc)?;
        for (k, v) in engine.call_named(&art.name, &inputs)? {
            if let Some(n) = k.strip_prefix("r_") {
                let t = v.into_f32();
                let e = acc.entry(n.to_string()).or_insert_with(|| vec![0.0; t.numel()]);
                for (a, b) in e.iter_mut().zip(&t.data) {
                    *a += b.abs();
                }
            }
        }
        batches += 1;
    }
    println!("relevances aggregated over {batches} validation batches");

    // the paper shows the input layer (left) and output layer (right);
    // we print every layer for completeness
    for name in pre.state.qnames() {
        let w = &pre.state.params[&name].data;
        let r = &acc[&name];
        let panel = correlation_panel(&name, w, r, 24);
        let share = small_weight_relevance_share(w, r);
        series_row(
            "panel",
            &[
                ("layer", name.clone()),
                ("c_value", format!("{:.4}", panel.c_value)),
                ("c_magnitude", format!("{:.4}", panel.c_magnitude)),
                ("small_w_rel_share", format!("{share:.4}")),
            ],
        );
    }
    println!("\ninput-layer histograms (Fig. 4 left panel):");
    let w0 = &pre.state.params["w0"].data;
    let panel = correlation_panel("w0", w0, &acc["w0"], 24);
    series_row("w0-weight-hist", &[("bins", format!("{:?}", panel.weight_hist))]);
    series_row("w0-relevance-hist", &[("bins", format!("{:?}", panel.relevance_hist))]);
    let rel_bins: Vec<String> =
        panel.relevance_by_weight_bin.iter().map(|v| format!("{v:.2}")).collect();
    series_row("w0-relevance-by-weight-bin", &[("bins", format!("{rel_bins:?}"))]);
    Ok(())
}
