//! Fig. 1 — sensitivity of weight vs activation quantization.
//!
//! Uniform post-training quantization (no re-training) of the pre-trained
//! MLP_GSC: sweep 2..8 bit separately over weights and activations and
//! report top-1 accuracy. Expected shape (the paper's claim): activations
//! degrade faster; < 8 bit needs QAT.

use ecqx::bench::{figure_header, series_row};
use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::coordinator::trainer::evaluate;
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::metrics::Meter;
use ecqx::quant::uniform_quantize;

fn main() -> anyhow::Result<()> {
    figure_header("Fig.1", "uniform PTQ sensitivity: weights vs activations (MLP_GSC)");
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (_, val) = exp::datasets(&model, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);
    let base = evaluate(&engine, &pre.state, &val_dl, ParamSource::Fp)?;
    series_row("baseline", &[("bits", "32".into()), ("acc", format!("{:.4}", base.accuracy))]);

    // weights: uniform symmetric PTQ per layer
    for bits in (2..=8).rev() {
        let mut state = exp::pretrained(&engine, &model, 17)?.state;
        for name in state.qnames() {
            let q = uniform_quantize(&state.params[&name], bits);
            state.params.insert(name, q);
        }
        let ev = evaluate(&engine, &state, &val_dl, ParamSource::Fp)?;
        series_row(
            "weights",
            &[("bits", bits.to_string()), ("acc", format!("{:.4}", ev.accuracy))],
        );
    }

    // activations: fake-quant eval artifact with dynamic per-tensor scale
    let art = engine.manifest.artifact("mlp_gsc_eval_actq")?.clone();
    for bits in (2..=8).rev() {
        let mut meter = Meter::new();
        for batch in val_dl.epoch(0) {
            let sc = Scalars { abits: bits as f32, ..Default::default() };
            let inputs = bind_inputs(&art, &pre.state, ParamSource::Fp, Some(&batch), &sc)?;
            let outs = engine.call_named(&art.name, &inputs)?;
            meter.update(
                outs["loss"].as_f32().as_scalar(),
                outs["correct"].as_f32().as_scalar(),
                batch.batch,
            );
        }
        series_row(
            "activations",
            &[("bits", bits.to_string()), ("acc", format!("{:.4}", meter.accuracy()))],
        );
    }
    Ok(())
}
