//! Table 1 — quantization results overview: accuracy, accuracy drop,
//! sparsity, compressed size (kB) and compression ratio for ECQ vs ECQ^x
//! at 2 and 4 bit, with the paper's three candidate criteria (highest
//! accuracy / highest CR without degradation / highest CR with negligible
//! degradation) selected from a small lambda grid.

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::sweep::select;
use ecqx::coordinator::Method;
use ecqx::exp;
use ecqx::metrics::{Table, WorkingPoint};
use sweep_common::{run_trials, Trial};

fn push_row(t: &mut Table, model: &str, kind: &str, wp: &WorkingPoint) {
    t.row(&[
        model.to_string(),
        format!("W{}A16", wp.bits),
        wp.method.clone(),
        kind.to_string(),
        format!("{:.2}", wp.accuracy * 100.0),
        format!("{:+.2}", wp.acc_drop * 100.0),
        format!("{:.2}", wp.sparsity * 100.0),
        format!("{:.2}", wp.size_bytes as f64 / 1000.0),
        format!("{:.2}", wp.compression_ratio),
    ]);
}

fn main() -> anyhow::Result<()> {
    figure_header("Table 1", "quantization results overview (2 + 4 bit, ECQ vs ECQx)");
    let engine = exp::engine()?;
    let mut table = Table::new(&[
        "Model", "Prec.", "Method", "criterion", "Acc(%)", "drop", "|W=0|/|W|(%)",
        "Size(kB)", "CR",
    ]);
    for (model, lambdas) in [
        (&exp::MLP_GSC, vec![6.0f32, 12.0]),
        (&exp::VGG_CIFAR, vec![8.0f32]),
    ] {
        for bits in [4u32, 2] {
            for method in [Method::Ecqx, Method::Ecq] {
                let trials: Vec<Trial> = lambdas
                    .iter()
                    .map(|&lambda| Trial { method, bits, lambda, p: 0.15 })
                    .collect();
                let series = format!("table1-{}-bw{bits}-{}", model.name, method.as_str());
                let pts = run_trials(&engine, model, &series, &trials, 1)?;
                if let Some(wp) = select::best_accuracy(&pts) {
                    push_row(&mut table, model.name, "best-acc", wp);
                }
                if let Some(wp) = select::best_cr_no_degradation(&pts) {
                    push_row(&mut table, model.name, "best-CR(no drop)", wp);
                }
                if let Some(wp) = select::best_cr_negligible(&pts, 0.02) {
                    push_row(&mut table, model.name, "best-CR(negl.)", wp);
                }
            }
        }
    }
    println!("\n{}", table.render());
    Ok(())
}
