//! Fig. 9 — bit-width variation on MLP_GSC: accuracy vs compressed memory
//! footprint for 2-5 bit ECQ^x. Expected shape: 2 bit minimizes the
//! bitstream; within 3-5 bit the size differences shrink (or invert) once
//! sparsity dominates the rate.

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::Method;
use ecqx::exp;
use sweep_common::{run_trials, Trial};

fn main() -> anyhow::Result<()> {
    figure_header("Fig.9", "MLP_GSC: accuracy vs memory footprint, 2-5 bit ECQx");
    let engine = exp::engine()?;
    for bits in 2..=5u32 {
        let trials: Vec<Trial> = [10.0f32]
            .iter()
            .map(|&lambda| Trial { method: Method::Ecqx, bits, lambda, p: 0.15 })
            .collect();
        run_trials(&engine, &exp::MLP_GSC, &format!("fig9-bw{bits}"), &trials, 1)?;
    }
    Ok(())
}
