//! Fig. 10 — bit-width variation on VGG: accuracy vs compressed memory
//! footprint for 2-5 bit ECQ^x (bench scale: one lambda per bit width).

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::Method;
use ecqx::exp;
use sweep_common::{run_trials, smoke_scaled, Trial};

fn main() -> anyhow::Result<()> {
    figure_header("Fig.10", "VGG: accuracy vs memory footprint, 2-5 bit ECQx");
    let engine = exp::engine()?;
    let vgg = smoke_scaled(&exp::VGG_CIFAR);
    for bits in 2..=5u32 {
        let trials = vec![Trial { method: Method::Ecqx, bits, lambda: 8.0, p: 0.15 }];
        run_trials(&engine, &vgg, &format!("fig10-bw{bits}"), &trials, 1)?;
    }
    Ok(())
}
