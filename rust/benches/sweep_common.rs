//! Shared driver for the figure-regeneration benches: runs (method, bits,
//! lambda, p) QAT trials from the cached pre-trained snapshot and prints
//! working-point rows in the paper's format.
//!
//! Bench trials run at CPU scale (1 QAT epoch, bench lambda grids);
//! paper-scale grids are available via the `ecqx sweep --paper-scale` CLI.

use ecqx::bench::series_row;
use ecqx::coordinator::sweep::{SweepConfig, SweepRunner};
use ecqx::coordinator::{AssignConfig, Method, QatConfig};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::metrics::WorkingPoint;
use ecqx::runtime::Engine;

pub struct Trial {
    pub method: Method,
    pub bits: u32,
    pub lambda: f32,
    pub p: f64,
}

/// Run a set of trials on one model, printing a row per working point.
pub fn run_trials(
    engine: &Engine,
    model: &exp::ModelExp,
    series: &str,
    trials: &[Trial],
    epochs: usize,
) -> anyhow::Result<Vec<WorkingPoint>> {
    let pre = exp::pretrained(engine, model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);
    let baseline = pre.baseline_acc;
    let runner = SweepRunner::new(engine, pre.state);
    let mut points = Vec::new();
    for t in trials {
        let cfg = SweepConfig {
            model: model.name.to_string(),
            method: t.method,
            bits: t.bits,
            lambdas: vec![t.lambda],
            p: t.p,
            qat: QatConfig {
                assign: AssignConfig {
                    method: t.method,
                    bits: t.bits,
                    lambda: t.lambda,
                    p: t.p,
                    ..Default::default()
                },
                epochs,
                lr: model.qat_lr * 4.0,
                verbose: false,
                ..Default::default()
            },
            baseline_acc: baseline,
        };
        let (wp, _) = runner.run_trial(&cfg, t.lambda, &train_dl, &val_dl)?;
        series_row(
            series,
            &[
                ("method", t.method.as_str().into()),
                ("bw", t.bits.to_string()),
                ("lambda", format!("{:.2}", t.lambda)),
                ("p", format!("{:.2}", t.p)),
                ("acc", format!("{:.4}", wp.accuracy)),
                ("drop", format!("{:+.4}", wp.acc_drop)),
                ("sparsity", format!("{:.4}", wp.sparsity)),
                ("size_kB", format!("{:.1}", wp.size_bytes as f64 / 1000.0)),
                ("CR", format!("{:.1}", wp.compression_ratio)),
            ],
        );
        points.push(wp);
    }
    Ok(points)
}
