//! Shared driver for the figure-regeneration benches: runs (method, bits,
//! lambda, p) QAT trials from the cached pre-trained snapshot and prints
//! working-point rows in the paper's format.
//!
//! Trials go through the `coordinator::campaign` worker pool; rows are
//! printed in grid order after completion, so the output is identical for
//! any job count. Bench trials run at CPU scale (1 QAT epoch, bench
//! lambda grids); paper-scale grids are available via the CLI
//! (`ecqx sweep --paper-scale [--jobs N]`).

use ecqx::bench::series_row;
use ecqx::coordinator::campaign::{self, CampaignOptions, TrialSpec};
use ecqx::coordinator::sweep::{SweepConfig, SweepRunner};
use ecqx::coordinator::{AssignConfig, Method, QatConfig};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::metrics::WorkingPoint;
use ecqx::runtime::Engine;

pub struct Trial {
    pub method: Method,
    pub bits: u32,
    pub lambda: f32,
    pub p: f64,
}

/// `$ECQX_BENCH_SMOKE=1` shrinks a model's dataset/pretraining scale so
/// the figure benches still emit their row contract inside CI's
/// bench-smoke budget (same convention as `perf_micro`). The pretrained
/// cache key includes `train_n`/epochs, so smoke baselines never pass
/// for full-scale ones.
#[allow(dead_code)]
pub fn smoke_scaled(model: &exp::ModelExp) -> exp::ModelExp {
    if std::env::var("ECQX_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false) {
        exp::ModelExp { train_n: 256, val_n: 128, pretrain_epochs: 1, ..*model }
    } else {
        *model
    }
}

/// Run a set of trials on one model serially, printing a row per working
/// point (the classic figure-bench driver).
#[allow(dead_code)]
pub fn run_trials(
    engine: &Engine,
    model: &exp::ModelExp,
    series: &str,
    trials: &[Trial],
    epochs: usize,
) -> anyhow::Result<Vec<WorkingPoint>> {
    run_trials_jobs(engine, model, series, trials, epochs, 1)
}

/// Parallel variant: fan the same trials over `jobs` campaign workers
/// sharing one engine. Rows print in grid order after the campaign
/// drains, so stdout (and the returned points) are identical to the
/// serial driver for any `jobs`.
#[allow(dead_code)]
pub fn run_trials_jobs(
    engine: &Engine,
    model: &exp::ModelExp,
    series: &str,
    trials: &[Trial],
    epochs: usize,
    jobs: usize,
) -> anyhow::Result<Vec<WorkingPoint>> {
    let pre = exp::pretrained(engine, model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);
    let runner = SweepRunner::new(engine, pre.state);
    // config template: per-trial method/bits/lambda/p come from the specs
    let cfg = SweepConfig {
        model: model.name.to_string(),
        method: Method::Ecqx,
        bits: 4,
        lambdas: vec![],
        p: 0.3,
        qat: QatConfig {
            assign: AssignConfig::default(),
            epochs,
            lr: model.qat_lr * 4.0,
            verbose: false,
            ..Default::default()
        },
        baseline_acc: pre.baseline_acc,
        seed: 17,
    };
    let specs: Vec<TrialSpec> = trials
        .iter()
        .enumerate()
        .map(|(id, t)| TrialSpec {
            id,
            method: t.method,
            bits: t.bits,
            lambda: t.lambda,
            p: t.p,
        })
        .collect();
    let opts = CampaignOptions { jobs, seed: cfg.seed, ..Default::default() };
    let points = campaign::run(
        &specs,
        &opts,
        |t, _seed| {
            runner
                .run_trial_spec(&cfg, t, &train_dl, &val_dl)
                .map(|(wp, _)| wp)
        },
        |_| {},
    )?;
    for wp in &points {
        series_row(
            series,
            &[
                ("method", wp.method.clone()),
                ("bw", wp.bits.to_string()),
                ("lambda", format!("{:.2}", wp.lambda)),
                ("p", format!("{:.2}", wp.p)),
                ("acc", format!("{:.4}", wp.accuracy)),
                ("drop", format!("{:+.4}", wp.acc_drop)),
                ("sparsity", format!("{:.4}", wp.sparsity)),
                ("size_kB", format!("{:.1}", wp.size_bytes as f64 / 1000.0)),
                ("CR", format!("{:.1}", wp.compression_ratio)),
            ],
        );
    }
    Ok(points)
}
