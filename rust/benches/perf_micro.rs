//! §Perf — micro-benchmarks of every hot path: the assign kernel
//! (engine-executed vs pure-rust), the CABAC codec, the engine call
//! overhead, and the full STE/LRP steps. These numbers back
//! EXPERIMENTS.md §Perf. Runs on whichever backend `exp::engine()`
//! resolves (PJRT over artifacts/, or the host reference backend when
//! those are absent — so the bench works fully offline).

use ecqx::bench::{bench, figure_header, throughput};
use ecqx::codec::{deepcabac, huffman};
use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::quant::{assign_ref, Codebook};
use ecqx::tensor::{Tensor, Value};
use ecqx::util::Rng;

fn main() -> anyhow::Result<()> {
    let engine = exp::engine()?;
    figure_header(
        "Perf",
        &format!("hot-path micro-benchmarks ({} backend)", engine.backend_name()),
    );
    let mut rng = Rng::new(7);

    // ---- L1: assignment kernel, 64k-element bucket ----
    let n = 65536;
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let cb = Codebook::fit(&w, 4);
    let r = vec![1.0f32; n];
    let mask = vec![1.0f32; n];
    let inputs = [
        Value::F32(Tensor::new(vec![n], w.clone())),
        Value::F32(Tensor::new(vec![n], r.clone())),
        Value::F32(Tensor::new(vec![n], mask.clone())),
        Value::F32(Tensor::new(vec![32], cb.values.clone())),
        Value::F32(Tensor::new(vec![32], cb.valid.clone())),
        Value::F32(Tensor::scalar(3e-4)),
    ];
    engine.call("assign_65536", &inputs)?; // compile outside the timing
    let res = bench("assign via engine (64k x 32)", 2, 10, || {
        engine.call("assign_65536", &inputs).unwrap()
    });
    println!("    -> {}", throughput(&res, n));
    let res = bench("assign_ref (pure rust, 64k x 32)", 2, 10, || {
        assign_ref(&w, &r, &mask, &cb, 3e-4)
    });
    println!("    -> {}", throughput(&res, n));

    // ---- codec throughput ----
    let levels: Vec<i32> = (0..262144)
        .map(|_| {
            if rng.chance(0.8) {
                0
            } else {
                let m = 1 + rng.below(7) as i32;
                if rng.chance(0.5) { m } else { -m }
            }
        })
        .collect();
    let enc = deepcabac::encode_levels(&levels);
    println!(
        "  cabac rate: {:.3} bits/weight ({} bytes for 256k weights)",
        enc.len() as f64 * 8.0 / levels.len() as f64,
        enc.len()
    );
    let res = bench("cabac encode 256k levels", 1, 10, || deepcabac::encode_levels(&levels));
    println!("    -> {}", throughput(&res, levels.len()));
    let res = bench("cabac decode 256k levels", 1, 10, || {
        deepcabac::decode_levels(&enc, levels.len())
    });
    println!("    -> {}", throughput(&res, levels.len()));
    let res = bench("huffman encode 256k levels", 1, 10, || huffman::encode(&levels));
    println!("    -> {}", throughput(&res, levels.len()));

    // ---- L3 <-> PJRT boundary: eval + ste step ----
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, _) = exp::datasets(&model, 17);
    let dl = DataLoader::new(&train, spec.batch, true, 1);
    let batch = dl.epoch(0).next().unwrap();
    let mut state = pre.state;
    // quantize once so q_ slots exist
    use ecqx::coordinator::{AssignConfig, Assigner, Method};
    let asg = Assigner::new(
        AssignConfig { method: Method::Ecq, bits: 4, lambda: 4.0, ..Default::default() },
        &state,
    );
    asg.assign_all(&engine, &mut state)?;

    let eval_art = engine.manifest.artifact("mlp_gsc_eval")?.clone();
    let ev_inputs =
        bind_inputs(&eval_art, &state, ParamSource::Quantized, Some(&batch), &Scalars::default())?;
    engine.call(&eval_art.name, &ev_inputs)?;
    bench("eval step (batch 128, 695k params)", 2, 10, || {
        engine.call(&eval_art.name, &ev_inputs).unwrap()
    });

    let ste_art = engine.manifest.artifact("mlp_gsc_ste_train")?.clone();
    let sc = Scalars { t: 1.0, lr: 1e-4, gs: 1.0, ..Default::default() };
    let ste_inputs = bind_inputs(&ste_art, &state, ParamSource::Fp, Some(&batch), &sc)?;
    engine.call(&ste_art.name, &ste_inputs)?;
    bench("ste_train step (fwd+bwd+Adam)", 2, 10, || {
        engine.call(&ste_art.name, &ste_inputs).unwrap()
    });

    let lrp_art = engine.manifest.artifact("mlp_gsc_lrp")?.clone();
    let lrp_inputs =
        bind_inputs(&lrp_art, &state, ParamSource::Quantized, Some(&batch), &Scalars::default())?;
    engine.call(&lrp_art.name, &lrp_inputs)?;
    bench("lrp step (per-weight relevances)", 2, 10, || {
        engine.call(&lrp_art.name, &lrp_inputs).unwrap()
    });

    // binder overhead in isolation (the host-side copy cost)
    bench("bind ste inputs (host copies)", 2, 20, || {
        bind_inputs(&ste_art, &state, ParamSource::Fp, Some(&batch), &sc).unwrap()
    });

    println!("\ncompile time total: {:.1}s", engine.compile_seconds());
    Ok(())
}
