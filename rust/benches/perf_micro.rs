//! §Perf — micro-benchmarks of every hot path: the blocked GEMM core vs
//! the retained naive kernels (`gemm_kernels` section), the assign kernel
//! (engine-executed vs pure-rust), the CABAC codec, the engine call
//! overhead, and the full STE/LRP steps. These numbers back
//! EXPERIMENTS.md §Perf. Runs on whichever backend `exp::engine()`
//! resolves (PJRT over artifacts/, or the host reference backend when
//! those are absent — so the bench works fully offline).
//!
//! Besides the human-readable output, every row lands in a
//! machine-readable `BENCH_host.json` (op, shape, ns/iter, GFLOP/s) —
//! `$ECQX_BENCH_JSON` overrides the path — so the repo's perf trajectory
//! is recorded run-over-run. `$ECQX_BENCH_SMOKE=1` shrinks iteration
//! counts and problem sizes and skips the model-level end-to-end section
//! (CI uses it to validate that the JSON contract holds without paying
//! for a pretrain).

use ecqx::bench::{bench, figure_header, throughput, PerfLog};
use ecqx::codec::{deepcabac, huffman};
use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::linalg::{
    self, conv2d_flops, gemm_flops, reference, Conv2d, Epilogue, GemmOpts, Kernel, Pad, Workspace,
};
use ecqx::quant::{assign_ref, Codebook};
use ecqx::tensor::{Tensor, Value};
use ecqx::util::Rng;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("ECQX_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    // iteration scaler: smoke mode runs every benchmark once, just enough
    // to prove the harness and the JSON contract
    let it = |n: usize| if smoke { 1 } else { n };
    let engine = exp::engine()?;
    let mut log = PerfLog::new(engine.backend_name());
    figure_header(
        "Perf",
        &format!(
            "hot-path micro-benchmarks ({} backend{})",
            engine.backend_name(),
            if smoke { ", smoke mode" } else { "" }
        ),
    );
    let mut rng = Rng::new(7);

    // ---- L0: the blocked GEMM core vs the retained naive kernels ----
    // 256^3 is the headline shape; the ragged shape guards the edge-tile
    // path from regressing unnoticed.
    let gemm_shapes: &[(usize, usize, usize)] =
        if smoke { &[(64, 64, 64)] } else { &[(256, 256, 256), (128, 512, 300)] };
    let mut ws = Workspace::new();
    for &(m, k, n) in gemm_shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let g: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let flops = Some(gemm_flops(m, k, n));
        let mut out_nn = vec![0.0f32; m * n];
        let mut out_tn = vec![0.0f32; k * n];
        let mut out_nt = vec![0.0f32; m * k];

        let r = bench(&format!("gemm_nn naive {m}x{k}x{n}"), it(1), it(10), || {
            reference::matmul(&a, &b, m, k, n)
        });
        log.push("gemm_nn_naive", &[m, k, n], &r, flops);
        let r = bench(&format!("gemm_nn blocked {m}x{k}x{n}"), it(1), it(10), || {
            linalg::gemm_nn(&mut ws, &a, &b, m, k, n, Epilogue::None, &mut out_nn)
        });
        log.push("gemm_nn_blocked", &[m, k, n], &r, flops);

        // TN/NT contract over a different axis; flops identical
        let r = bench(&format!("gemm_tn naive {m}x{k}x{n}"), it(1), it(10), || {
            reference::matmul_tn(&a, &g, m, k, n)
        });
        log.push("gemm_tn_naive", &[m, k, n], &r, flops);
        let r = bench(&format!("gemm_tn blocked {m}x{k}x{n}"), it(1), it(10), || {
            linalg::gemm_tn(&mut ws, &a, &g, m, k, n, Epilogue::None, &mut out_tn)
        });
        log.push("gemm_tn_blocked", &[m, k, n], &r, flops);

        let r = bench(&format!("gemm_nt naive {m}x{k}x{n}"), it(1), it(10), || {
            reference::matmul_nt(&g, &b, m, n, k)
        });
        log.push("gemm_nt_naive", &[m, k, n], &r, flops);
        let r = bench(&format!("gemm_nt blocked {m}x{k}x{n}"), it(1), it(10), || {
            linalg::gemm_nt(&mut ws, &g, &b, m, n, k, Epilogue::None, &mut out_nt)
        });
        log.push("gemm_nt_blocked", &[m, k, n], &r, flops);

        // fused bias+relu epilogue vs the old separate full-tensor passes
        let r = bench(&format!("qdense fused bias+relu {m}x{k}x{n}"), it(1), it(10), || {
            linalg::gemm_nn(&mut ws, &a, &b, m, k, n, Epilogue::BiasRelu(&bias), &mut out_nn)
        });
        log.push("qdense_fused_bias_relu", &[m, k, n], &r, flops);
        let r = bench(&format!("qdense unfused (naive+2 passes) {m}x{k}x{n}"), it(1), it(10), || {
            let mut z = reference::matmul(&a, &b, m, k, n);
            for row in z.chunks_exact_mut(n) {
                for (zv, &bv) in row.iter_mut().zip(&bias) {
                    *zv = (*zv + bv).max(0.0);
                }
            }
            z
        });
        log.push("qdense_unfused", &[m, k, n], &r, flops);

        // codebook-gather weights at the paper's sparsity (~80% zero
        // centroid): pack-time dequantization vs materializing [k,n]
        let cbv = [0.0f32, 0.5, -0.5, 0.25, -0.25, 0.75, -0.75, 1.0];
        let idx: Vec<i32> = (0..k * n)
            .map(|_| if rng.chance(0.8) { 0 } else { 1 + rng.below(7) as i32 })
            .collect();
        let r = bench(&format!("qdense_gather pack-fused {m}x{k}x{n}"), it(1), it(10), || {
            let epi = Epilogue::Bias(&bias);
            linalg::gemm_gather_nn(&mut ws, &a, &idx, &cbv, m, k, n, epi, &mut out_nn)
        });
        log.push("qdense_gather_packed", &[m, k, n], &r, flops);
        let r = bench(&format!("qdense_gather materialized {m}x{k}x{n}"), it(1), it(10), || {
            let w: Vec<f32> = idx.iter().map(|&s| cbv[s.clamp(0, 7) as usize]).collect();
            let mut z = reference::matmul(&a, &w, m, k, n);
            for row in z.chunks_exact_mut(n) {
                for (zv, &bv) in row.iter_mut().zip(&bias) {
                    *zv += bv;
                }
            }
            z
        });
        log.push("qdense_gather_materialized", &[m, k, n], &r, flops);
    }
    // ---- simd_kernels: every available micro-kernel on one shape ----
    // One row per Kernel variant this host can run (scalar always;
    // avx2/neon when detected), each tagged with the variant being timed
    // and what runtime dispatch would pick — scripts/perf_compare and
    // CI's bench-smoke key on these rows, so the section must emit even
    // in smoke mode.
    {
        let (m, k, n) = if smoke { (64, 64, 64) } else { (256, 256, 256) };
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let flops = Some(gemm_flops(m, k, n));
        let dispatch = GemmOpts::dispatch().kernel.name();
        let mut out = vec![0.0f32; m * n];
        for kernel in Kernel::available() {
            let opts = GemmOpts::with_kernel(kernel);
            let r = bench(
                &format!("gemm_nn {} kernel {m}x{k}x{n}", kernel.name()),
                it(1),
                it(10),
                || linalg::gemm_nn_with(opts, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out),
            );
            log.push_kv(
                "simd_gemm_nn",
                &[m, k, n],
                &r,
                flops,
                &[("kernel", kernel.name()), ("dispatch", dispatch)],
            );
        }
    }

    // ---- lut_kernels: sparse LUT matmul vs the gather-GEMM oracle ----
    // The deployment-form dense layer at the paper's working points:
    // bit-width in {2, 4} × zero-centroid sparsity p in {0.5, 0.9}. The
    // LUT kernel's op count (`lut_ops`: nnz adds + 2 per active centroid)
    // shrinks with p and bits while gather-GEMM stays at 2·m·k·n; both
    // the timing and the op count land in the JSON ("ops" key), and CI's
    // bench-smoke asserts lut ops < gather flops at p ≥ 0.5. Emits in
    // smoke mode — the rows are part of the JSON contract.
    {
        let (m, k, n) = if smoke { (16, 64, 64) } else { (128, 256, 256) };
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; m * n];
        for &bits in &[2u32, 4] {
            let side = (1usize << (bits - 1)) - 1;
            let mut cbv = vec![0.0f32];
            for s in 1..=side {
                cbv.push(s as f32 * 0.25);
                cbv.push(-(s as f32) * 0.25);
            }
            for &p in &[0.5f64, 0.9] {
                let idx: Vec<i32> = (0..k * n)
                    .map(|_| {
                        if rng.chance(p) { 0 } else { 1 + rng.below(cbv.len() - 1) as i32 }
                    })
                    .collect();
                let variant = format!("b{bits}_p{p}");
                let lut_work = linalg::lut_ops(&idx, &cbv, m, k, n);
                let ops = format!("{lut_work:.0}");
                let r = bench(&format!("lut_qdense {variant} {m}x{k}x{n}"), it(1), it(10), || {
                    linalg::lut_matmul(&mut ws, &a, &idx, &cbv, m, k, n, Epilogue::None, &mut out)
                });
                log.push_kv(
                    "lut_qdense",
                    &[m, k, n],
                    &r,
                    Some(lut_work),
                    &[("variant", &variant), ("ops", &ops)],
                );
                let ops = format!("{:.0}", gemm_flops(m, k, n));
                let r =
                    bench(&format!("gather_qdense {variant} {m}x{k}x{n}"), it(1), it(10), || {
                        linalg::gemm_gather_nn(
                            &mut ws,
                            &a,
                            &idx,
                            &cbv,
                            m,
                            k,
                            n,
                            Epilogue::None,
                            &mut out,
                        )
                    });
                log.push_kv(
                    "gather_qdense",
                    &[m, k, n],
                    &r,
                    Some(gemm_flops(m, k, n)),
                    &[("variant", &variant), ("ops", &ops)],
                );
            }
        }
    }

    // ---- conv kernels: the im2col-GEMM lowering vs naive direct conv ----
    // CIFAR-shaped sizes: the cnn_cifar stem (32×32×3 -> 16) and a mid
    // stack layer (16×16×32 -> 64, stride 2); shape column is the full
    // geometry [n, h, w, kh, kw, cin, cout, stride] so BENCH_host.json
    // rows stay unique across future non-square / non-3×3 cases.
    let conv_cases: &[Conv2d] = if smoke {
        &[Conv2d { n: 2, h: 8, w: 8, c: 3, kh: 3, kw: 3, co: 8, stride: 1, pad: Pad::Same }]
    } else {
        &[
            Conv2d { n: 8, h: 32, w: 32, c: 3, kh: 3, kw: 3, co: 16, stride: 1, pad: Pad::Same },
            Conv2d { n: 8, h: 16, w: 16, c: 32, kh: 3, kw: 3, co: 64, stride: 2, pad: Pad::Same },
        ]
    };
    for g in conv_cases {
        let shape = [g.n, g.h, g.w, g.kh, g.kw, g.c, g.co, g.stride];
        let tag = format!("{}x{}x{}x{}->{} s{}", g.n, g.h, g.w, g.c, g.co, g.stride);
        let x: Vec<f32> = (0..g.in_len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let wf: Vec<f32> = (0..g.filter_len()).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let gout: Vec<f32> = (0..g.out_len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..g.co).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let flops = Some(conv2d_flops(g));
        let mut out = vec![0.0f32; g.out_len()];
        let mut dwf = vec![0.0f32; g.filter_len()];
        let mut dx = vec![0.0f32; g.in_len()];

        let r = bench(&format!("conv2d naive {tag}"), it(1), it(10), || {
            reference::conv2d_naive(&x, &wf, g)
        });
        log.push("conv2d_naive", &shape, &r, flops);
        let r = bench(&format!("conv2d im2col {tag}"), it(1), it(10), || {
            linalg::conv2d(&mut ws, &x, &wf, g, Epilogue::None, &mut out)
        });
        log.push("conv2d_im2col", &shape, &r, flops);
        let r = bench(&format!("conv2d im2col fused bias+relu {tag}"), it(1), it(10), || {
            linalg::conv2d(&mut ws, &x, &wf, g, Epilogue::BiasRelu(&bias), &mut out)
        });
        log.push("conv2d_im2col_bias_relu", &shape, &r, flops);

        let r = bench(&format!("conv2d_bwd_filter naive {tag}"), it(1), it(10), || {
            reference::conv2d_bwd_filter_naive(&x, &gout, g)
        });
        log.push("conv2d_bwd_filter_naive", &shape, &r, flops);
        let r = bench(&format!("conv2d_bwd_filter im2col {tag}"), it(1), it(10), || {
            linalg::conv2d_bwd_filter(&mut ws, &x, &gout, g, Epilogue::None, &mut dwf)
        });
        log.push("conv2d_bwd_filter_im2col", &shape, &r, flops);

        let r = bench(&format!("conv2d_bwd_input naive {tag}"), it(1), it(10), || {
            reference::conv2d_bwd_input_naive(&gout, &wf, g)
        });
        log.push("conv2d_bwd_input_naive", &shape, &r, flops);
        let r = bench(&format!("conv2d_bwd_input im2col {tag}"), it(1), it(10), || {
            linalg::conv2d_bwd_input(&mut ws, &gout, &wf, g, &mut dx)
        });
        log.push("conv2d_bwd_input_im2col", &shape, &r, flops);
    }
    println!("  (gemm workspace high-water mark: {} KiB)", ws.reserved_bytes() / 1024);

    // ---- L1: assignment kernel ----
    let n = if smoke { 4096 } else { 65536 };
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let cb = Codebook::fit(&w, 4);
    let r = vec![1.0f32; n];
    let mask = vec![1.0f32; n];
    let inputs = [
        Value::F32(Tensor::new(vec![n], w.clone())),
        Value::F32(Tensor::new(vec![n], r.clone())),
        Value::F32(Tensor::new(vec![n], mask.clone())),
        Value::F32(Tensor::new(vec![32], cb.values.clone())),
        Value::F32(Tensor::new(vec![32], cb.valid.clone())),
        Value::F32(Tensor::scalar(3e-4)),
    ];
    let assign_art = format!("assign_{n}");
    engine.call(&assign_art, &inputs)?; // compile outside the timing
    let res = bench(&format!("assign via engine ({n} x 32)"), it(2), it(10), || {
        engine.call(&assign_art, &inputs).unwrap()
    });
    println!("    -> {}", throughput(&res, inputs[0].numel()));
    log.push("assign_engine", &[n, 32], &res, None);
    let res = bench(&format!("assign_ref (pure rust, {n} x 32)"), it(2), it(10), || {
        assign_ref(&w, &r, &mask, &cb, 3e-4)
    });
    println!("    -> {}", throughput(&res, n));
    log.push("assign_ref", &[n, 32], &res, None);

    // ---- codec throughput ----
    let nlev = if smoke { 16384 } else { 262144 };
    let levels: Vec<i32> = (0..nlev)
        .map(|_| {
            if rng.chance(0.8) {
                0
            } else {
                let m = 1 + rng.below(7) as i32;
                if rng.chance(0.5) { m } else { -m }
            }
        })
        .collect();
    let enc = deepcabac::encode_levels(&levels);
    println!(
        "  cabac rate: {:.3} bits/weight ({} bytes for {}k weights)",
        enc.len() as f64 * 8.0 / levels.len() as f64,
        enc.len(),
        nlev / 1024
    );
    let res = bench("cabac encode levels", it(1), it(10), || deepcabac::encode_levels(&levels));
    println!("    -> {}", throughput(&res, levels.len()));
    log.push("cabac_encode", &[nlev], &res, None);
    let res = bench("cabac decode levels", it(1), it(10), || {
        deepcabac::decode_levels(&enc, levels.len()).unwrap()
    });
    println!("    -> {}", throughput(&res, levels.len()));
    log.push("cabac_decode", &[nlev], &res, None);
    let res =
        bench("huffman encode levels", it(1), it(10), || huffman::encode(&levels).unwrap());
    println!("    -> {}", throughput(&res, levels.len()));
    log.push("huffman_encode", &[nlev], &res, None);

    // ---- L3 <-> engine boundary: eval + ste + lrp steps ----
    // Skipped in smoke mode: the section needs a pre-trained model, and
    // CI's contract check only needs the sections above.
    if !smoke {
        let model = exp::MLP_GSC;
        let pre = exp::pretrained(&engine, &model, 17)?;
        let spec = engine.manifest.model(model.name)?.clone();
        let (train, _) = exp::datasets(&model, 17);
        let dl = DataLoader::new(&train, spec.batch, true, 1);
        let batch = dl.epoch(0).next().unwrap();
        let mut state = pre.state;
        // quantize once so q_ slots exist
        use ecqx::coordinator::{AssignConfig, Assigner, Method};
        let asg = Assigner::new(
            AssignConfig { method: Method::Ecq, bits: 4, lambda: 4.0, ..Default::default() },
            &state,
        );
        asg.assign_all(&engine, &mut state)?;

        let eval_art = engine.manifest.artifact("mlp_gsc_eval")?.clone();
        let ev_inputs = bind_inputs(
            &eval_art,
            &state,
            ParamSource::Quantized,
            Some(&batch),
            &Scalars::default(),
        )?;
        engine.call(&eval_art.name, &ev_inputs)?;
        let res = bench("eval step (batch 128, 695k params)", 2, 10, || {
            engine.call(&eval_art.name, &ev_inputs).unwrap()
        });
        log.push("e2e_eval_step", &[spec.batch], &res, None);

        let ste_art = engine.manifest.artifact("mlp_gsc_ste_train")?.clone();
        let sc = Scalars { t: 1.0, lr: 1e-4, gs: 1.0, ..Default::default() };
        let ste_inputs = bind_inputs(&ste_art, &state, ParamSource::Fp, Some(&batch), &sc)?;
        engine.call(&ste_art.name, &ste_inputs)?;
        let res = bench("ste_train step (fwd+bwd+Adam)", 2, 10, || {
            engine.call(&ste_art.name, &ste_inputs).unwrap()
        });
        log.push("e2e_ste_step", &[spec.batch], &res, None);

        let lrp_art = engine.manifest.artifact("mlp_gsc_lrp")?.clone();
        let lrp_inputs = bind_inputs(
            &lrp_art,
            &state,
            ParamSource::Quantized,
            Some(&batch),
            &Scalars::default(),
        )?;
        engine.call(&lrp_art.name, &lrp_inputs)?;
        let res = bench("lrp step (per-weight relevances)", 2, 10, || {
            engine.call(&lrp_art.name, &lrp_inputs).unwrap()
        });
        log.push("e2e_lrp_step", &[spec.batch], &res, None);

        // binder overhead in isolation (the host-side copy cost)
        let res = bench("bind ste inputs (host copies)", 2, 20, || {
            bind_inputs(&ste_art, &state, ParamSource::Fp, Some(&batch), &sc).unwrap()
        });
        log.push("bind_ste_inputs", &[spec.batch], &res, None);
    }

    println!("\ncompile time total: {:.1}s", engine.compile_seconds());
    let path = log.write_default()?;
    println!("perf rows written to {} ({} rows)", path.display(), log.len());
    Ok(())
}
