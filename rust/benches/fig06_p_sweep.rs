//! Fig. 6 — target-sparsity hyperparameter p controls the LRP-introduced
//! sparsity: accuracy-vs-sparsity working points for several p at fixed
//! bit width 4 on MLP_GSC. Expected shape: small p wins at low sparsity,
//! larger p trades accuracy for extra LRP sparsity.

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::figure_header;
use ecqx::coordinator::Method;
use ecqx::exp;
use sweep_common::{run_trials, Trial};

fn main() -> anyhow::Result<()> {
    figure_header("Fig.6", "hyperparameter p controls LRP-introduced sparsity (MLP_GSC, 4 bit)");
    let engine = exp::engine()?;
    let mut trials = Vec::new();
    for &lambda in &[10.0f32] {
        for &p in &[0.05f64, 0.2, 0.4] {
            trials.push(Trial { method: Method::Ecqx, bits: 4, lambda, p });
        }
    }
    run_trials(&engine, &exp::MLP_GSC, "fig6", &trials, 1)?;
    Ok(())
}
