//! Sec. 5.2.2 — LRP training-time overhead: wall-clock per QAT epoch for
//! ECQ^x vs ECQ across the model architectures. The paper reports
//! 1.2x / 2.4x / 3.2x for MLP_GSC / VGG16 / ResNet18 (dense layers need
//! one extra backward, conv/BN alpha-beta layers two).

use ecqx::bench::{figure_header, series_row};
use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::util::Timer;

fn epoch_seconds(
    engine: &ecqx::runtime::Engine,
    model: &exp::ModelExp,
    method: Method,
) -> anyhow::Result<(f64, f64, f64)> {
    let pre = exp::pretrained(engine, model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);
    let cfg = QatConfig {
        assign: AssignConfig { method, bits: 4, lambda: 8.0, p: 0.15, ..Default::default() },
        epochs: 1,
        lr: model.qat_lr,
        lrp_warmup: 4,
        verbose: false,
        ..Default::default()
    };
    let mut state = pre.state;
    let t = Timer::start();
    let out = QatTrainer::new(cfg).run(engine, &mut state, &train_dl, &val_dl)?;
    let total = t.elapsed_s();
    Ok((total, out.profile.total("lrp") + out.profile.total("lrp_warmup"),
        out.profile.total("ste_step")))
}

fn main() -> anyhow::Result<()> {
    figure_header("Sec.5.2.2", "LRP training-time overhead: ECQx vs ECQ epoch wall-clock");
    let engine = exp::engine()?;
    for model in [&exp::MLP_GSC, &exp::VGG_CIFAR, &exp::RESNET_VOC] {
        let (ecq_s, _, ecq_ste) = epoch_seconds(&engine, model, Method::Ecq)?;
        let (ecqx_s, lrp_s, _) = epoch_seconds(&engine, model, Method::Ecqx)?;
        series_row(
            "overhead",
            &[
                ("model", model.name.into()),
                ("ecq_epoch_s", format!("{ecq_s:.1}")),
                ("ecqx_epoch_s", format!("{ecqx_s:.1}")),
                ("ratio", format!("{:.2}x", ecqx_s / ecq_s.max(1e-9))),
                ("lrp_share_s", format!("{lrp_s:.1}")),
                ("ste_share_s", format!("{ecq_ste:.1}")),
            ],
        );
    }
    println!("paper reference ratios: MLP 1.2x, VGG 2.4x, ResNet 3.2x");
    Ok(())
}
