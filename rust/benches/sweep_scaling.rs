//! §Perf — parallel sweep orchestrator scaling: the same 4-trial lambda
//! grid run at 1 job and at 4 jobs must produce bitwise-identical rows,
//! with the 4-job campaign measurably faster on a multi-core host
//! (trials are independent; on PJRT the sharded executable cache keeps
//! workers on uncontended read locks, on the host backend the kernels
//! are pure functions). Runs offline on the host backend when
//! `artifacts/` or real PJRT bindings are absent.

#[path = "sweep_common.rs"]
mod sweep_common;

use ecqx::bench::{figure_header, series_row};
use ecqx::coordinator::Method;
use ecqx::exp;
use ecqx::util::Timer;
use sweep_common::{run_trials_jobs, Trial};

fn main() -> anyhow::Result<()> {
    let engine = exp::engine()?;
    figure_header(
        "Perf.sweep",
        &format!(
            "parallel campaign: 4-trial grid, 1 vs 4 jobs ({} backend)",
            engine.backend_name()
        ),
    );
    let trials: Vec<Trial> = [0.0f32, 0.02, 0.08, 0.25]
        .iter()
        .map(|&lambda| Trial { method: Method::Ecqx, bits: 4, lambda, p: 0.3 })
        .collect();

    // warmup: pretrained-baseline cache + artifact compiles land outside
    // the timed sections
    run_trials_jobs(&engine, &exp::MLP_GSC, "warmup", &trials[..1], 1, 1)?;

    let t = Timer::start();
    let serial = run_trials_jobs(&engine, &exp::MLP_GSC, "sweep-1job", &trials, 1, 1)?;
    let serial_s = t.elapsed_s();

    let t = Timer::start();
    let par = run_trials_jobs(&engine, &exp::MLP_GSC, "sweep-4job", &trials, 1, 4)?;
    let par_s = t.elapsed_s();

    let identical = serial.len() == par.len()
        && serial.iter().zip(&par).all(|(a, b)| a.to_csv() == b.to_csv());
    series_row(
        "par-scaling",
        &[
            ("trials", trials.len().to_string()),
            ("serial_s", format!("{serial_s:.2}")),
            ("par4_s", format!("{par_s:.2}")),
            ("speedup", format!("{:.2}", serial_s / par_s.max(1e-9))),
            ("identical_rows", identical.to_string()),
        ],
    );
    assert!(identical, "parallel rows must be bitwise identical to serial");
    Ok(())
}
