//! Ablation — pruning criterion quality (the DESIGN.md §5 ablation):
//! prune each layer of the pre-trained MLP to a fixed fraction by
//! (a) weight magnitude, (b) LRP relevance (validation-set aggregated),
//! (c) random, and evaluate without any re-training.
//!
//! This isolates the paper's core claim (Sec. 4.2, Fig. 4): relevance
//! identifies prunable weights that magnitude misses, with the gap
//! opening in the high-sparsity regime. Also ablates STE gradient
//! scaling (Fig. 5 step 3).

use ecqx::bench::{figure_header, series_row};
use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::coordinator::trainer::evaluate;
use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::nn::QLayer;
use ecqx::quant::Codebook;
use ecqx::tensor::{Tensor, TensorI32};
use ecqx::util::Rng;
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    figure_header("Ablation", "pruning criterion: magnitude vs LRP relevance vs random");
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(&model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 3);
    let val_dl = DataLoader::new(&val, spec.batch, false, 3);

    // validation-aggregated relevances (score-weighted)
    let art = engine.manifest.artifact("mlp_gsc_lrp")?.clone();
    let mut rel: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    for batch in train_dl.epoch(0).take(16) {
        let sc = Scalars::default();
        let inputs = bind_inputs(&art, &pre.state, ParamSource::Fp, Some(&batch), &sc)?;
        for (k, v) in engine.call_named(&art.name, &inputs)? {
            if let Some(n) = k.strip_prefix("r_") {
                let t = v.into_f32();
                let e = rel.entry(n.to_string()).or_insert_with(|| vec![0.0; t.numel()]);
                for (a, b) in e.iter_mut().zip(&t.data) {
                    *a += b.abs();
                }
            }
        }
    }

    let mut rng = Rng::new(99);
    for frac in [0.5f64, 0.7, 0.8, 0.9] {
        for mode in ["magnitude", "relevance", "random"] {
            let mut state = exp::pretrained(&engine, &model, 17)?.state;
            for name in state.qnames() {
                let w = state.params[&name].clone();
                let score: Vec<f32> = match mode {
                    "magnitude" => w.data.iter().map(|x| x.abs()).collect(),
                    "relevance" => rel[&name].clone(),
                    _ => (0..w.numel()).map(|_| rng.f32()).collect(),
                };
                let mut order: Vec<usize> = (0..w.numel()).collect();
                order.sort_by(|&a, &b| score[a].partial_cmp(&score[b]).unwrap());
                let cut = (w.numel() as f64 * frac) as usize;
                let mut qw = w.data.clone();
                let mut idx = vec![1i32; w.numel()];
                for &i in &order[..cut] {
                    qw[i] = 0.0;
                    idx[i] = 0;
                }
                state.qlayers.insert(
                    name.clone(),
                    QLayer {
                        qw: Tensor::new(w.shape.clone(), qw),
                        idx: TensorI32::new(w.shape.clone(), idx),
                        codebook: Codebook::fit(&w.data, 4),
                    },
                );
            }
            let ev = evaluate(&engine, &state, &val_dl, ParamSource::Quantized)?;
            series_row(
                "criterion",
                &[
                    ("frac", format!("{frac:.1}")),
                    ("mode", mode.into()),
                    ("acc", format!("{:.4}", ev.accuracy)),
                ],
            );
        }
    }

    // structured (row/column) vs unstructured pruning at matched sparsity
    // (paper §2: structure constraints cost accuracy at equal sparsity)
    println!();
    use ecqx::quant::structured::{sparsify_structured, GroupKind, GroupSaliency};
    for frac in [0.5f64, 0.7] {
        for (label, kind) in [("rows", GroupKind::Row), ("cols", GroupKind::Column)] {
            let mut state = exp::pretrained(&engine, &model, 17)?.state;
            for name in state.qnames() {
                let w = state.params[&name].clone();
                let res = sparsify_structured(&w, None, kind, GroupSaliency::L1, frac);
                let idx: Vec<i32> =
                    res.weights.data.iter().map(|&v| (v != 0.0) as i32).collect();
                state.qlayers.insert(
                    name.clone(),
                    QLayer {
                        qw: res.weights.clone(),
                        idx: TensorI32::new(w.shape.clone(), idx),
                        codebook: Codebook::fit(&w.data, 4),
                    },
                );
            }
            let ev = evaluate(&engine, &state, &val_dl, ParamSource::Quantized)?;
            series_row(
                "structured",
                &[
                    ("frac", format!("{frac:.1}")),
                    ("groups", label.into()),
                    ("acc", format!("{:.4}", ev.accuracy)),
                ],
            );
        }
    }

    // integer-grid vs Lloyd-refined centroids (the paper's Sec. 3.1 choice)
    println!();
    use ecqx::quant::refine::ablate_refinement;
    use ecqx::quant::assign_ref;
    {
        let state = exp::pretrained(&engine, &model, 17)?.state;
        let w = &state.params["w1"].data;
        let cb = Codebook::fit(w, 4);
        let ones = vec![1.0f32; w.len()];
        let a = assign_ref(w, &ones, &ones, &cb, 1e-4);
        let ab = ablate_refinement(w, &a, &cb, 2);
        series_row(
            "centroid-refine",
            &[
                ("integer_grid_mse", format!("{:.3e}", ab.integer_grid_mse)),
                ("lloyd_refined_mse", format!("{:.3e}", ab.refined_mse)),
                ("integer_cost", format!("{:.3}x", ab.integer_cost)),
            ],
        );
    }

    // STE gradient-scaling ablation (Fig. 5 step 3)
    println!();
    for gs in [true, false] {
        let cfg = QatConfig {
            assign: AssignConfig {
                method: Method::Ecq,
                bits: 4,
                lambda: 10.0,
                p: 0.15,
                ..Default::default()
            },
            epochs: 1,
            lr: model.qat_lr * 4.0,
            grad_scale: gs,
            verbose: false,
            ..Default::default()
        };
        let mut state = exp::pretrained(&engine, &model, 17)?.state;
        let out = QatTrainer::new(cfg).run(&engine, &mut state, &train_dl, &val_dl)?;
        series_row(
            "grad-scale",
            &[
                ("enabled", gs.to_string()),
                ("val_acc", format!("{:.4}", out.epochs.last().unwrap().val_acc)),
                ("sparsity", format!("{:.4}", out.final_sparsity)),
            ],
        );
    }
    Ok(())
}
