"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package has an exact reference here; pytest +
hypothesis assert allclose across shapes/seeds. The rust test-suite
additionally cross-checks the lowered HLO artifacts against a third,
pure-rust implementation.
"""

import jax
import jax.numpy as jnp

BIG = 1e30
P_EPS = 1e-9


def matmul_ref(a, b):
    return jnp.matmul(a, b)


def qdense_ref(a, w, b):
    return jnp.matmul(a, w) + b[None, :]


def qdense_gather_ref(a, idx, codebook, b):
    return jnp.matmul(a, jnp.take(codebook, idx, axis=0)) + b[None, :]


def lrp_dense_rw_ref(a, s, w):
    """R_w = w * (a^T @ s), the epsilon-rule per-weight relevance."""
    return w * jnp.matmul(a.T, s)


def assign_ref(w, r, mask, centroids, cvalid, lam):
    """Reference two-phase ECQ^x assignment (Eq. 11), no Pallas.

    Identical semantics to ecqx_assign.assign_full.
    """
    # Phase 1: nearest-neighbour source distribution.
    d2 = (w[:, None] - centroids[None, :]) ** 2
    d2m = d2 + (1.0 - cvalid)[None, :] * BIG
    nn = jnp.argmin(d2m, axis=1)
    onehot = jax.nn.one_hot(nn, centroids.shape[0], dtype=jnp.float32)
    counts = jnp.sum(onehot * mask[:, None], axis=0)
    total = jnp.maximum(jnp.sum(mask), 1.0)
    probs = counts / total
    entcost = -lam * jnp.log2(jnp.maximum(probs, P_EPS))
    entcost = entcost + (1.0 - cvalid) * BIG
    # Phase 2: relevance-adjusted cost argmin.
    cost = d2 + entcost[None, :]
    zero_cost = r * cost[:, 0]
    cost = cost.at[:, 0].set(zero_cost)
    idx = jnp.argmin(cost, axis=1).astype(jnp.int32)
    qw = jnp.take(centroids, idx, axis=0)
    idx = jnp.where(mask > 0.5, idx, 0)
    qw = qw * mask
    onehot2 = jax.nn.one_hot(idx, centroids.shape[0], dtype=jnp.float32)
    fcounts = jnp.sum(onehot2 * mask[:, None], axis=0)
    return idx, qw, fcounts
