"""L1 Pallas kernel: the ECQ^x assignment function (Eq. 11 of the paper).

Given the flattened full-precision weights of a layer, the centroid
codebook, per-cluster entropy costs and per-weight relevance factors, the
kernel computes for every weight the assignment cost to every centroid

    cost[i, c] = (w_i - centroid_c)^2 + entcost_c          (c != 0)
    cost[i, 0] = r_i * ((w_i - centroid_0)^2 + entcost_0)  (zero cluster)

with entcost_c = -lambda^(l) * log2(P_c) (+inf for invalid codebook
slots), and assigns each weight to the argmin centroid. `r_i` is the
rho-scaled LRP relevance factor (== 1.0 everywhere for plain ECQ).

Layout decisions (TPU-shaped, run under interpret=True on CPU):
  * the flat weight vector streams through VMEM in BLK-element blocks,
  * the codebook is tiny (K_MAX = 32 slots, slot 0 == zero centroid) and
    resident across all grid steps,
  * one artifact per power-of-two size bucket serves every layer and
    every bit width: padding is masked out via `mask`, unused codebook
    slots are +inf entcost.

The surrounding two-phase probability computation (nearest-neighbour
counts -> P_c) lives in `assign_full` below (L2, plain jnp) and is lowered
into the same HLO artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Codebook capacity: 2^5 - 1 = 31 centroids (5 bit) padded to 32 lanes.
K_MAX = 32
# Elements streamed per grid step.
BLK = 8192


def _assign_kernel(w_ref, r_ref, cen_ref, entcost_ref, idx_ref, qw_ref):
    w = w_ref[...]  # [BLK]
    r = r_ref[...]  # [BLK]
    cen = cen_ref[...]  # [K_MAX]
    ent = entcost_ref[...]  # [K_MAX]
    # [BLK, K_MAX] squared distances + information-content cost.
    d2 = (w[:, None] - cen[None, :]) ** 2
    cost = d2 + ent[None, :]
    # Zero-cluster cost is scaled by the relevance factor (Eq. 11).
    zero_cost = r * cost[:, 0]
    cost = cost.at[:, 0].set(zero_cost)
    idx = jnp.argmin(cost, axis=1).astype(jnp.int32)
    idx_ref[...] = idx
    qw_ref[...] = jnp.take(cen, idx, axis=0)


@functools.partial(jax.jit, static_argnames=("blk",))
def assign_pallas(w, r, centroids, entcost, blk=BLK):
    """Run the assignment kernel over a flat (padded) weight vector.

    Args:
      w: f32[N] flattened weights, N a multiple of blk.
      r: f32[N] relevance factors for the zero cluster (1.0 == neutral).
      centroids: f32[K_MAX], slot 0 must be the zero centroid.
      entcost: f32[K_MAX], -lambda*log2(P_c); +BIG for invalid slots.

    Returns:
      (idx i32[N], qw f32[N]) centroid indices and dequantized weights.
    """
    n = w.shape[0]
    blk = min(blk, n)
    assert n % blk == 0, (n, blk)
    grid = (n // blk,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((K_MAX,), lambda i: (0,)),
            pl.BlockSpec((K_MAX,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(w, r, centroids, entcost)


BIG = 1e30  # cost for invalid codebook slots
P_EPS = 1e-9  # probability floor (empty clusters)


def cluster_probs(w, mask, centroids, cvalid):
    """Phase 1: nearest-neighbour cluster probabilities P_c.

    P_c is the fraction of (valid) weights whose nearest centroid is c —
    the source distribution the entropy constraint is computed from."""
    d2 = (w[:, None] - centroids[None, :]) ** 2
    d2 = d2 + (1.0 - cvalid)[None, :] * BIG
    nn = jnp.argmin(d2, axis=1)
    # scatter-add histogram (much cheaper than a one-hot matmul; §Perf)
    counts = jnp.zeros(centroids.shape[0], jnp.float32).at[nn].add(mask)
    total = jnp.maximum(jnp.sum(mask), 1.0)
    return counts / total, counts


def assign_full(w, r, mask, centroids, cvalid, lam):
    """Full two-phase ECQ^x assignment for one layer (lowered to HLO).

    Args:
      w: f32[N] padded flat weights.
      r: f32[N] relevance factors (zero-cluster cost scale).
      mask: f32[N] 1 for real elements, 0 for bucket padding.
      centroids: f32[K_MAX] codebook, slot 0 == 0.0.
      cvalid: f32[K_MAX] 1 for valid slots.
      lam: f32 scalar, the layer-scaled Lagrange multiplier lambda^(l).

    Returns:
      idx i32[N], qw f32[N], counts f32[K_MAX] (final assignment counts).
    """
    probs, _ = cluster_probs(w, mask, centroids, cvalid)
    entcost = -lam * jnp.log2(jnp.maximum(probs, P_EPS))
    entcost = entcost + (1.0 - cvalid) * BIG
    idx, qw = assign_pallas(w, r, centroids, entcost)
    # Padding elements are forced into the zero cluster and excluded from
    # the reported counts.
    idx = jnp.where(mask > 0.5, idx, 0)
    qw = qw * mask
    counts = jnp.zeros(centroids.shape[0], jnp.float32).at[idx].add(mask)
    return idx, qw, counts
