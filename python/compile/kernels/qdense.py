"""L1 Pallas kernel: tiled dense matmul for the quantized-dense hot path.

The MXU-shaped workhorse of the stack. Every dense layer in every model
(forward *and* the custom-VJP backward) routes through `matmul`, so the
QAT hot path exercises the Pallas kernel end to end. `qdense` adds the
bias; `qdense_gather` is the inference-time variant that dequantizes
integer centroid indices through a codebook before the matmul (the
"integer weights + look-up table" deployment mode of the paper).

Kernels are lowered with interpret=True (CPU PJRT cannot run Mosaic
custom-calls); the BlockSpec structure — (BM, BK) x (BK, BN) tiles with a
K-accumulation grid axis — is the layout a real TPU would use, with the
default 128 tile matching the MXU systolic array.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge: matches the 128x128 MXU systolic array.
TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (BM, BN) output tile; grid axis 2 accumulates over K blocks."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, multiples):
    """Zero-pad trailing dims of `x` up to the given multiples."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a, b, bm=TILE, bk=TILE, bn=TILE):
    """Pallas tiled matmul a @ b with zero-padding to tile multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


@jax.custom_vjp
def pmatmul(a, b):
    """Differentiable wrapper: Pallas matmul with a hand-written VJP
    (pallas_call has no transpose rule), whose backward passes also run
    through the Pallas kernel."""
    return matmul(a, b)


def _pmatmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    return matmul(g, b.T), matmul(a.T, g)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


def qdense(a, w, b):
    """Dense layer y = a @ w + b through the Pallas matmul (differentiable)."""
    return pmatmul(a, w) + b[None, :]


def qdense_gather(a, idx, codebook, b):
    """Inference-time quantized dense layer.

    Weights are stored as int32 centroid indices `idx` (shape [I, J]) into
    a per-layer `codebook` (shape [K]); they are dequantized by gather and
    fed to the Pallas matmul. This is the deployment representation the
    paper targets (integer weights + LUT)."""
    w = jnp.take(codebook, idx, axis=0)
    return matmul(a, w) + b[None, :]
