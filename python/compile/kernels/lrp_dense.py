"""L1 Pallas kernel: per-weight LRP relevance aggregation for dense layers.

For the epsilon-rule on a dense layer (Eq. 5/6 of the paper), the
relevance of weight w_ij aggregated over a batch is

    R_w[i, j] = sum_b a[b, i] * w[i, j] * s[b, j]
              = w[i, j] * (a^T @ s)[i, j]

with s[b, j] = R_out[b, j] / (z[b, j] + eps * sign(z[b, j])) the
"upstream modified gradient". The batch contraction is an MXU matmul;
the elementwise scale by w is fused into the final K-step of the same
kernel, so the whole aggregation is a single Pallas call.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _lrp_dense_kernel(a_ref, s_ref, w_ref, o_ref, *, nsteps):
    """Accumulate (a^T s) tiles over the batch axis; scale by w at the end."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, s_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == nsteps - 1)
    def _scale():
        o_ref[...] *= w_ref[...]


def _pad_to(x, multiples):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, multiples)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bb"))
def lrp_dense_rw(a, s, w, bi=TILE, bj=TILE, bb=TILE):
    """Per-weight relevance R_w = w * (a^T @ s) via the Pallas kernel.

    Args:
      a: f32[B, I] layer inputs.
      s: f32[B, J] upstream relevance / stabilized pre-activations.
      w: f32[I, J] layer weights.
    Returns:
      f32[I, J] batch-aggregated per-weight relevances.
    """
    bsz, i = a.shape
    _, j = s.shape
    assert w.shape == (i, j), (a.shape, s.shape, w.shape)
    bi, bj, bb = min(bi, i), min(bj, j), min(bb, bsz)
    ap = _pad_to(a, (bb, bi))
    sp = _pad_to(s, (bb, bj))
    wp = _pad_to(w, (bi, bj))
    bp, ip = ap.shape
    _, jp = sp.shape
    nsteps = bp // bb
    grid = (ip // bi, jp // bj, nsteps)
    out = pl.pallas_call(
        functools.partial(_lrp_dense_kernel, nsteps=nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bi), lambda i_, j_, k_: (k_, i_)),
            pl.BlockSpec((bb, bj), lambda i_, j_, k_: (k_, j_)),
            pl.BlockSpec((bi, bj), lambda i_, j_, k_: (i_, j_)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i_, j_, k_: (i_, j_)),
        out_shape=jax.ShapeDtypeStruct((ip, jp), jnp.float32),
        interpret=True,
    )(ap, sp, wp)
    return out[:i, :j]


def stabilize(z, eps):
    """z + eps * sign(z) with sign(0) := 1 (paper Sec. 4.1)."""
    sgn = jnp.where(z >= 0, 1.0, -1.0)
    return z + eps * sgn
