"""L2: JAX model definitions, LRP composite backward, and training steps.

Three model families from the paper's evaluation:
  * MLP_GSC    — 360-512-512-256-256-128-128-12 MLP (Google Speech Commands)
  * VGG_CIFAR  — VGG-slim conv net for 32x32x3 (CIFAR-10), +BatchNorm variant
  * RESNET_VOC — ResNet-lite with residual blocks + BN (Pascal VOC, 20 cls)

Each model provides: a parameter specification (the single source of truth
for the rust side, exported via the manifest), a forward pass whose dense
layers run through the L1 Pallas matmul kernel, a composite-LRP backward
(epsilon-rule for dense layers, alpha-beta rule with beta=1 for conv and
BatchNorm layers — Sec. 4.1 of the paper) producing *per-weight*
relevances, and the train/eval steps that are AOT-lowered to HLO text.

Everything here is build-time Python; at experiment time only the rust
coordinator runs, executing the lowered artifacts via PJRT.
"""

from collections import namedtuple

import jax
import jax.numpy as jnp

from .kernels import qdense
from .kernels.lrp_dense import lrp_dense_rw, stabilize

EPS = 1e-6  # epsilon-rule stabilizer
ALPHA, BETA = 2.0, 1.0  # alpha-beta rule parameters (paper: beta = 1)

# name: parameter name; shape: tuple; init: he_in|zeros|ones;
# quantize: True for weight tensors that ECQ(x) quantizes.
ParamSpec = namedtuple("ParamSpec", "name shape init quantize")


# --------------------------------------------------------------------------
# shared building blocks
# --------------------------------------------------------------------------


def conv2d(x, w, stride=1, padding="SAME"):
    """NHWC x HWIO conv."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        (stride, stride),
        padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


def bn_stats(x):
    mu = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    return mu, var


def bn_fwd(x, gamma, beta):
    """Batch-statistics BatchNorm (used in train and eval; see DESIGN.md)."""
    mu, var = bn_stats(x)
    return (x - mu) / jnp.sqrt(var + 1e-5) * gamma + beta


def softmax_xent(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def correct_count(logits, y):
    return jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


# --------------------------------------------------------------------------
# LRP decomposition rules (Sec. 4.1)
# --------------------------------------------------------------------------


def lrp_relevance_init(logits, y, eqw):
    """Initial relevance at the output layer.

    Default (eqw=0): the target-class score f(x)_y, so samples are weighted
    by prediction confidence. eqw=1: equally-weighted samples (R_n = 1,
    the Fig. 4 setting)."""
    onehot = jax.nn.one_hot(y, logits.shape[1], dtype=jnp.float32)
    score = jnp.sum(onehot * logits, axis=1, keepdims=True)
    weight = jnp.where(eqw > 0.5, 1.0, score)
    return onehot * weight


def lrp_dense_eps(a, w, b, r_out):
    """Epsilon-rule for a dense layer -> (R_in, per-weight R_w).

    R_w aggregation runs through the L1 Pallas kernel."""
    z = jnp.matmul(a, w) + b[None, :]
    s = r_out / stabilize(z, EPS)
    r_in = a * jnp.matmul(s, w.T)
    r_w = lrp_dense_rw(a, s, w)
    return r_in, r_w


def _conv_vjp_x(w, s, x_shape, stride, padding):
    zeros = jnp.zeros(x_shape, jnp.float32)
    _, vjp = jax.vjp(lambda t: conv2d(t, w, stride, padding), zeros)
    return vjp(s)[0]


def _conv_vjp_w(x, s, w_shape, stride, padding):
    zeros = jnp.zeros(w_shape, jnp.float32)
    _, vjp = jax.vjp(lambda t: conv2d(x, t, stride, padding), zeros)
    return vjp(s)[0]


def lrp_conv_ab(a, w, b, r_out, stride=1, padding="SAME"):
    """Alpha-beta rule (alpha=2, beta=1) for a conv layer.

    Positive/negative contribution split: (a_i w_ij)^+ = a+w+ + a-w-,
    (a_i w_ij)^- = a+w- + a-w+. Relevance messages are aggregated over all
    filter application contexts k (Eq. 7) via conv VJPs, per-weight
    relevance via the `w (x) correlation(a, s)` identity."""
    ap, an = jnp.maximum(a, 0.0), jnp.minimum(a, 0.0)
    wp, wn = jnp.maximum(w, 0.0), jnp.minimum(w, 0.0)
    bp, bn_ = jnp.maximum(b, 0.0), jnp.minimum(b, 0.0)
    zp = conv2d(ap, wp, stride, padding) + conv2d(an, wn, stride, padding) + bp
    zn = conv2d(ap, wn, stride, padding) + conv2d(an, wp, stride, padding) + bn_
    sp = r_out / stabilize(zp, EPS)
    sn = r_out / stabilize(zn, EPS)
    xs, ws = a.shape, w.shape
    r_in = ALPHA * (
        ap * _conv_vjp_x(wp, sp, xs, stride, padding)
        + an * _conv_vjp_x(wn, sp, xs, stride, padding)
    ) - BETA * (
        ap * _conv_vjp_x(wn, sn, xs, stride, padding)
        + an * _conv_vjp_x(wp, sn, xs, stride, padding)
    )
    r_w = ALPHA * (
        wp * _conv_vjp_w(ap, sp, ws, stride, padding)
        + wn * _conv_vjp_w(an, sp, ws, stride, padding)
    ) - BETA * (
        wn * _conv_vjp_w(ap, sn, ws, stride, padding)
        + wp * _conv_vjp_w(an, sn, ws, stride, padding)
    )
    return r_in, r_w


def lrp_bn_ab(a, gamma, beta, r_out):
    """Alpha-beta rule (beta=1) through a (non-canonized) BatchNorm layer.

    BN acts as a per-channel diagonal linear map z = a*u + c with
    u = gamma/sqrt(var+eps); the bias term absorbs its share of relevance
    (paper Sec. 5.2.2: layers kept separate, not merged)."""
    mu, var = bn_stats(a)
    u = gamma / jnp.sqrt(var + 1e-5)
    c = beta - mu * u
    au = a * u
    zp = jnp.maximum(au, 0.0) + jnp.maximum(c, 0.0)
    zn = jnp.minimum(au, 0.0) + jnp.minimum(c, 0.0)
    sp = r_out / stabilize(zp, EPS)
    sn = r_out / stabilize(zn, EPS)
    return ALPHA * jnp.maximum(au, 0.0) * sp - BETA * jnp.minimum(au, 0.0) * sn


def lrp_maxpool(a, r_out, k=2):
    """Winner-take-all redistribution through maxpool."""
    z, vjp = jax.vjp(lambda t: maxpool(t, k), a)
    s = r_out / stabilize(z, EPS)
    return a * vjp(s)[0]


def lrp_add(x1, x2, r_out):
    """Proportional (epsilon) split over a residual addition."""
    s = r_out / stabilize(x1 + x2, EPS)
    return x1 * s, x2 * s


def lrp_gap(a, r_out):
    """Global average pooling: relevance proportional to contributions."""
    z = jnp.mean(a, axis=(1, 2))
    s = r_out / stabilize(z, EPS)
    hw = a.shape[1] * a.shape[2]
    return a * s[:, None, None, :] / hw


# --------------------------------------------------------------------------
# MLP_GSC
# --------------------------------------------------------------------------

MLP_DIMS = [360, 512, 512, 256, 256, 128, 128, 12]


class MlpGsc:
    """MLP for (synthetic) Google Speech Commands keyword spotting."""

    name = "mlp_gsc"
    batch = 128
    input_shape = (360,)
    num_classes = 12

    def param_specs(self):
        specs = []
        for i, (din, dout) in enumerate(zip(MLP_DIMS[:-1], MLP_DIMS[1:])):
            specs.append(ParamSpec(f"w{i}", (din, dout), "he_in", True))
            specs.append(ParamSpec(f"b{i}", (dout,), "zeros", False))
        return specs

    def forward(self, p, x, collect=False):
        nl = len(MLP_DIMS) - 1
        acts = [x]
        a = x
        for i in range(nl):
            z = qdense.qdense(a, p[f"w{i}"], p[f"b{i}"])
            a = jax.nn.relu(z) if i < nl - 1 else z
            if collect and i < nl - 1:
                acts.append(a)
        return (a, acts) if collect else a

    def lrp(self, p, x, y, eqw):
        """Composite LRP (epsilon-rule throughout; MLP has only dense
        layers) -> per-weight relevances, batch-aggregated, signed."""
        logits, acts = self.forward(p, x, collect=True)
        r = lrp_relevance_init(logits, y, eqw)
        nl = len(MLP_DIMS) - 1
        rws = {}
        for i in reversed(range(nl)):
            r, rw = lrp_dense_eps(acts[i], p[f"w{i}"], p[f"b{i}"], r)
            rws[f"w{i}"] = rw
        return rws


# --------------------------------------------------------------------------
# VGG_CIFAR (plain and BatchNorm variants)
# --------------------------------------------------------------------------

VGG_CFG = [32, 32, "M", 64, 64, "M", 128, 128, "M"]
VGG_FC = [2048, 256, 10]


class VggCifar:
    """VGG-slim for (synthetic) CIFAR-10; `bn=True` adds BatchNorm after
    every conv layer (the Fig. 8 variant)."""

    batch = 32
    input_shape = (32, 32, 3)
    num_classes = 10

    def __init__(self, bn=False):
        self.bn = bn
        self.name = "vgg_cifar_bn" if bn else "vgg_cifar"

    def param_specs(self):
        specs = []
        cin = 3
        ci = 0
        for v in VGG_CFG:
            if v == "M":
                continue
            specs.append(ParamSpec(f"c{ci}", (3, 3, cin, v), "he_in", True))
            specs.append(ParamSpec(f"cb{ci}", (v,), "zeros", False))
            if self.bn:
                specs.append(ParamSpec(f"g{ci}", (v,), "ones", False))
                specs.append(ParamSpec(f"be{ci}", (v,), "zeros", False))
            cin = v
            ci += 1
        for i, (din, dout) in enumerate(zip(VGG_FC[:-1], VGG_FC[1:])):
            specs.append(ParamSpec(f"w{i}", (din, dout), "he_in", True))
            specs.append(ParamSpec(f"b{i}", (dout,), "zeros", False))
        return specs

    def forward(self, p, x, collect=False):
        cache = {"conv_in": [], "bn_in": [], "pool_in": []}
        a = x
        ci = 0
        for v in VGG_CFG:
            if v == "M":
                if collect:
                    cache["pool_in"].append(a)
                a = maxpool(a)
            else:
                if collect:
                    cache["conv_in"].append(a)
                a = conv2d(a, p[f"c{ci}"]) + p[f"cb{ci}"]
                if self.bn:
                    if collect:
                        cache["bn_in"].append(a)
                    a = bn_fwd(a, p[f"g{ci}"], p[f"be{ci}"])
                a = jax.nn.relu(a)
                ci += 1
        a = a.reshape(a.shape[0], -1)
        cache["fc_in"] = [a]
        a = jax.nn.relu(qdense.qdense(a, p["w0"], p["b0"]))
        cache["fc_in"].append(a)
        logits = qdense.qdense(a, p["w1"], p["b1"])
        return (logits, cache) if collect else logits

    def lrp(self, p, x, y, eqw):
        """Composite LRP: epsilon-rule for dense, alpha-beta (beta=1) for
        conv and BatchNorm layers."""
        logits, cache = self.forward(p, x, collect=True)
        r = lrp_relevance_init(logits, y, eqw)
        rws = {}
        r, rws["w1"] = lrp_dense_eps(cache["fc_in"][1], p["w1"], p["b1"], r)
        r, rws["w0"] = lrp_dense_eps(cache["fc_in"][0], p["w0"], p["b0"], r)
        # back through the conv stack
        last = cache["conv_in"][-1].shape  # only for static structure
        del last
        conv_idx = sum(1 for v in VGG_CFG if v != "M") - 1
        pool_idx = VGG_CFG.count("M") - 1
        pre_flat = cache["pool_in"][-1]
        # undo flatten: relevance at last pool output
        r = r.reshape(maxpool(pre_flat).shape)
        for v in reversed(VGG_CFG):
            if v == "M":
                r = lrp_maxpool(cache["pool_in"][pool_idx], r)
                pool_idx -= 1
            else:
                if self.bn:
                    r = lrp_bn_ab(
                        cache["bn_in"][conv_idx],
                        p[f"g{conv_idx}"],
                        p[f"be{conv_idx}"],
                        r,
                    )
                r, rw = lrp_conv_ab(
                    cache["conv_in"][conv_idx],
                    p[f"c{conv_idx}"],
                    p[f"cb{conv_idx}"],
                    r,
                )
                rws[f"c{conv_idx}"] = rw
                conv_idx -= 1
        return rws


# --------------------------------------------------------------------------
# RESNET_VOC (ResNet-lite with BasicBlocks + BN)
# --------------------------------------------------------------------------


class ResNetVoc:
    """ResNet-lite: conv stem + 4 BasicBlocks (one strided with a 1x1
    downsample path) + GAP + linear head; 20-class (synthetic) Pascal VOC."""

    name = "resnet_voc"
    batch = 32
    input_shape = (32, 32, 3)
    num_classes = 20

    # (block_id, cin, cout, stride)
    BLOCKS = [(0, 32, 32, 1), (1, 32, 32, 1), (2, 32, 64, 2), (3, 64, 64, 1)]

    def param_specs(self):
        specs = [
            ParamSpec("stem", (3, 3, 3, 32), "he_in", True),
            ParamSpec("stem_g", (32,), "ones", False),
            ParamSpec("stem_be", (32,), "zeros", False),
        ]
        for bid, cin, cout, stride in self.BLOCKS:
            specs.append(ParamSpec(f"b{bid}_c1", (3, 3, cin, cout), "he_in", True))
            specs.append(ParamSpec(f"b{bid}_g1", (cout,), "ones", False))
            specs.append(ParamSpec(f"b{bid}_be1", (cout,), "zeros", False))
            specs.append(ParamSpec(f"b{bid}_c2", (3, 3, cout, cout), "he_in", True))
            specs.append(ParamSpec(f"b{bid}_g2", (cout,), "ones", False))
            specs.append(ParamSpec(f"b{bid}_be2", (cout,), "zeros", False))
            if stride != 1 or cin != cout:
                specs.append(ParamSpec(f"b{bid}_ds", (1, 1, cin, cout), "he_in", True))
                specs.append(ParamSpec(f"b{bid}_dsg", (cout,), "ones", False))
                specs.append(ParamSpec(f"b{bid}_dsbe", (cout,), "zeros", False))
        specs.append(ParamSpec("fc_w", (64, 20), "he_in", True))
        specs.append(ParamSpec("fc_b", (20,), "zeros", False))
        return specs

    def _block_fwd(self, p, bid, stride, has_ds, a, cache=None):
        if cache is not None:
            cache[f"b{bid}_in"] = a
        h = conv2d(a, p[f"b{bid}_c1"], stride)
        if cache is not None:
            cache[f"b{bid}_bn1_in"] = h
        h = jax.nn.relu(bn_fwd(h, p[f"b{bid}_g1"], p[f"b{bid}_be1"]))
        if cache is not None:
            cache[f"b{bid}_c2_in"] = h
        h = conv2d(h, p[f"b{bid}_c2"])
        if cache is not None:
            cache[f"b{bid}_bn2_in"] = h
        h = bn_fwd(h, p[f"b{bid}_g2"], p[f"b{bid}_be2"])
        if has_ds:
            s = conv2d(a, p[f"b{bid}_ds"], stride)
            if cache is not None:
                cache[f"b{bid}_dsbn_in"] = s
            s = bn_fwd(s, p[f"b{bid}_dsg"], p[f"b{bid}_dsbe"])
        else:
            s = a
        if cache is not None:
            cache[f"b{bid}_main"] = h
            cache[f"b{bid}_skip"] = s
        return jax.nn.relu(h + s)

    def forward(self, p, x, collect=False):
        cache = {} if collect else None
        if collect:
            cache["stem_in"] = x
        a = conv2d(x, p["stem"])
        if collect:
            cache["stem_bn_in"] = a
        a = jax.nn.relu(bn_fwd(a, p["stem_g"], p["stem_be"]))
        for bid, cin, cout, stride in self.BLOCKS:
            has_ds = stride != 1 or cin != cout
            a = self._block_fwd(p, bid, stride, has_ds, a, cache)
        if collect:
            cache["gap_in"] = a
        a = jnp.mean(a, axis=(1, 2))
        if collect:
            cache["fc_in"] = a
        logits = qdense.qdense(a, p["fc_w"], p["fc_b"])
        return (logits, cache) if collect else logits

    def lrp(self, p, x, y, eqw):
        logits, cache = self.forward(p, x, collect=True)
        r = lrp_relevance_init(logits, y, eqw)
        rws = {}
        r, rws["fc_w"] = lrp_dense_eps(cache["fc_in"], p["fc_w"], p["fc_b"], r)
        r = lrp_gap(cache["gap_in"], r)
        zero_b = jnp.zeros  # conv layers here have no bias
        for bid, cin, cout, stride in reversed(self.BLOCKS):
            has_ds = stride != 1 or cin != cout
            r_main, r_skip = lrp_add(cache[f"b{bid}_main"], cache[f"b{bid}_skip"], r)
            # main path: bn2 <- conv2 <- relu <- bn1 <- conv1
            r_main = lrp_bn_ab(
                cache[f"b{bid}_bn2_in"], p[f"b{bid}_g2"], p[f"b{bid}_be2"], r_main
            )
            r_main, rw = lrp_conv_ab(
                cache[f"b{bid}_c2_in"],
                p[f"b{bid}_c2"],
                zero_b((cout,), jnp.float32),
                r_main,
            )
            rws[f"b{bid}_c2"] = rw
            r_main = lrp_bn_ab(
                cache[f"b{bid}_bn1_in"], p[f"b{bid}_g1"], p[f"b{bid}_be1"], r_main
            )
            r_main, rw = lrp_conv_ab(
                cache[f"b{bid}_in"],
                p[f"b{bid}_c1"],
                zero_b((cout,), jnp.float32),
                r_main,
                stride=stride,
            )
            rws[f"b{bid}_c1"] = rw
            if has_ds:
                r_skip = lrp_bn_ab(
                    cache[f"b{bid}_dsbn_in"], p[f"b{bid}_dsg"], p[f"b{bid}_dsbe"], r_skip
                )
                r_skip, rw = lrp_conv_ab(
                    cache[f"b{bid}_in"],
                    p[f"b{bid}_ds"],
                    zero_b((cout,), jnp.float32),
                    r_skip,
                    stride=stride,
                )
                rws[f"b{bid}_ds"] = rw
            r = r_main + r_skip
        r = lrp_bn_ab(cache["stem_bn_in"], p["stem_g"], p["stem_be"], r)
        _, rws["stem"] = lrp_conv_ab(
            cache["stem_in"], p["stem"], zero_b((32,), jnp.float32), r
        )
        return rws


# --------------------------------------------------------------------------
# Optimizer + training / eval steps (the AOT entry points)
# --------------------------------------------------------------------------


def adam_update(p, g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mh = m / (1.0 - b1**t)
    vh = v / (1.0 - b2**t)
    return p - lr * mh / (jnp.sqrt(vh) + eps), m, v


def fp_train_step(model, params, m, v, x, y, t, lr):
    """Plain FP32 Adam step (pre-training / unquantized baseline)."""

    def loss_fn(p):
        logits = model.forward(p, x)
        return softmax_xent(logits, y), logits

    grads, logits = jax.grad(loss_fn, has_aux=True)(params)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        new_p[k], new_m[k], new_v[k] = adam_update(
            params[k], grads[k], m[k], v[k], t, lr
        )
    return new_p, new_m, new_v, softmax_xent(logits, y), correct_count(logits, y)


def ste_train_step(model, params_fp, qw, m, v, x, y, t, lr, gs):
    """ECQ(x) STE step (Fig. 5 steps 1, 3-5).

    Forward/backward through the *quantized* model (quantized weight slots
    hold `qw`), gradients of quantized weights optionally scaled by the
    magnitude of their (non-zero) centroid value, then Adam-applied to the
    full-precision background model."""
    qnames = {s.name for s in model.param_specs() if s.quantize}

    def loss_fn(p):
        logits = model.forward(p, x)
        return softmax_xent(logits, y), logits

    eval_params = {k: (qw[k] if k in qnames else params_fp[k]) for k in params_fp}
    grads, logits = jax.grad(loss_fn, has_aux=True)(eval_params)
    new_p, new_m, new_v = {}, {}, {}
    for k in params_fp:
        g = grads[k]
        if k in qnames:
            scale = jnp.where(qw[k] != 0.0, jnp.abs(qw[k]), 1.0)
            g = g * jnp.where(gs > 0.5, scale, 1.0)
        new_p[k], new_m[k], new_v[k] = adam_update(
            params_fp[k], g, m[k], v[k], t, lr
        )
    return new_p, new_m, new_v, softmax_xent(logits, y), correct_count(logits, y)


def eval_step(model, params, x, y):
    logits = model.forward(params, x)
    return softmax_xent(logits, y), correct_count(logits, y)


def lrp_step(model, params, x, y, eqw):
    """Per-weight LRP relevances of the (quantized) model for one batch."""
    return model.lrp(params, x, y, eqw)


def act_fake_quant(x, levels):
    """Uniform fake-quantization of a non-negative activation tensor to
    `levels` levels (per-tensor dynamic scale) — the Fig. 1 activation
    sensitivity probe."""
    mx = jnp.maximum(jnp.max(x), 1e-8)
    s = mx / (levels - 1.0)
    return jnp.round(x / s) * s


def eval_actq_mlp(model, params, x, y, abits):
    """MLP eval with uniformly quantized post-ReLU activations."""
    levels = 2.0**abits
    nl = len(MLP_DIMS) - 1
    a = x
    for i in range(nl):
        z = qdense.qdense(a, params[f"w{i}"], params[f"b{i}"])
        if i < nl - 1:
            a = act_fake_quant(jax.nn.relu(z), levels)
        else:
            a = z
    return softmax_xent(a, y), correct_count(a, y)


def eval_actq_vgg(model, params, x, y, abits):
    """VGG eval with uniformly quantized post-ReLU activations."""
    levels = 2.0**abits
    a = x
    ci = 0
    for vv in VGG_CFG:
        if vv == "M":
            a = maxpool(a)
        else:
            a = conv2d(a, params[f"c{ci}"]) + params[f"cb{ci}"]
            a = act_fake_quant(jax.nn.relu(a), levels)
            ci += 1
    a = a.reshape(a.shape[0], -1)
    a = act_fake_quant(jax.nn.relu(qdense.qdense(a, params["w0"], params["b0"])), levels)
    logits = qdense.qdense(a, params["w1"], params["b1"])
    return softmax_xent(logits, y), correct_count(logits, y)


def eval_gather_mlp(model, params_other, idx, codebooks, x, y):
    """MLP eval in deployment form: int32 centroid indices + per-layer
    codebook, dequantized through the L1 gather kernel."""
    nl = len(MLP_DIMS) - 1
    a = x
    for i in range(nl):
        z = qdense.qdense_gather(
            a, idx[f"w{i}"], codebooks[f"w{i}"], params_other[f"b{i}"]
        )
        a = jax.nn.relu(z) if i < nl - 1 else z
    return softmax_xent(a, y), correct_count(a, y)


MODELS = {
    "mlp_gsc": MlpGsc,
    "vgg_cifar": lambda: VggCifar(bn=False),
    "vgg_cifar_bn": lambda: VggCifar(bn=True),
    "resnet_voc": ResNetVoc,
}


def get_model(name):
    return MODELS[name]()
