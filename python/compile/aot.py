"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts + manifest.

Runs once at build time (`make artifacts`); the rust coordinator then loads
`artifacts/*.hlo.txt` via PJRT and never touches python again.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest (artifacts/manifest.txt) is the single source of truth the
rust side parses: model/param tables, artifact input/output signatures,
and a source hash for incremental rebuilds.
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ecqx_assign

K_MAX = ecqx_assign.K_MAX

# Power-of-two element-count buckets served by the shared assign kernel.
ASSIGN_BUCKETS = [
    1024,
    2048,
    4096,
    16384,
    32768,
    65536,
    131072,
    262144,
    524288,
]


def bucket_for(n):
    for b in ASSIGN_BUCKETS:
        if n <= b:
            return b
    raise ValueError(f"layer of {n} elements exceeds largest assign bucket")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_shape(shape):
    return "scalar" if len(shape) == 0 else "x".join(str(d) for d in shape)


class Sig:
    """Ordered flat input/output signature of one artifact."""

    def __init__(self):
        self.ins = []  # (name, dtype_str, shape)
        self.outs = []

    def add_in(self, name, shape, dtype="f32"):
        self.ins.append((name, dtype, tuple(shape)))

    def add_out(self, name, shape, dtype="f32"):
        self.outs.append((name, dtype, tuple(shape)))

    def in_specs(self):
        dt = {"f32": jnp.float32, "i32": jnp.int32}
        return [_spec(s, dt[d]) for (_, d, s) in self.ins]


def _param_sig(sig, model, prefix="p_"):
    for s in model.param_specs():
        sig.add_in(prefix + s.name, s.shape)


def build_model_artifacts(model):
    """Return list of (name, lowered_fn, Sig) for one model."""
    specs = model.param_specs()
    names = [s.name for s in specs]
    qnames = [s.name for s in specs if s.quantize]
    bsz = model.batch
    xshape = (bsz,) + model.input_shape
    arts = []

    def unflatten(args, groups):
        """Split flat positional args into dicts per group of names."""
        out = []
        i = 0
        for g in groups:
            out.append({k: args[i + j] for j, k in enumerate(g)})
            i += len(g)
        return out, args[i:]

    # ---- fp_train ----
    sig = Sig()
    _param_sig(sig, model)
    for n in names:
        sig.add_in("m_" + n, dict((s.name, s.shape) for s in specs)[n])
    for n in names:
        sig.add_in("v_" + n, dict((s.name, s.shape) for s in specs)[n])
    sig.add_in("x", xshape)
    sig.add_in("y", (bsz,), "i32")
    sig.add_in("t", ())
    sig.add_in("lr", ())

    def fp_train(*args):
        (p, m, v), rest = unflatten(args, [names, names, names])
        x, y, t, lr = rest
        np_, nm, nv, loss, corr = M.fp_train_step(model, p, m, v, x, y, t, lr)
        return (
            tuple(np_[n] for n in names)
            + tuple(nm[n] for n in names)
            + tuple(nv[n] for n in names)
            + (loss, corr)
        )

    for pre in ("p_", "m_", "v_"):
        for s in specs:
            sig.add_out(pre + s.name, s.shape)
    sig.add_out("loss", ())
    sig.add_out("correct", ())
    arts.append((f"{model.name}_fp_train", fp_train, sig))

    # ---- ste_train ----
    sig = Sig()
    _param_sig(sig, model)
    shp = dict((s.name, s.shape) for s in specs)
    for n in qnames:
        sig.add_in("q_" + n, shp[n])
    for n in names:
        sig.add_in("m_" + n, shp[n])
    for n in names:
        sig.add_in("v_" + n, shp[n])
    sig.add_in("x", xshape)
    sig.add_in("y", (bsz,), "i32")
    sig.add_in("t", ())
    sig.add_in("lr", ())
    sig.add_in("gs", ())

    def ste_train(*args):
        (p, q, m, v), rest = unflatten(args, [names, qnames, names, names])
        x, y, t, lr, gs = rest
        np_, nm, nv, loss, corr = M.ste_train_step(
            model, p, q, m, v, x, y, t, lr, gs
        )
        return (
            tuple(np_[n] for n in names)
            + tuple(nm[n] for n in names)
            + tuple(nv[n] for n in names)
            + (loss, corr)
        )

    for pre in ("p_", "m_", "v_"):
        for s in specs:
            sig.add_out(pre + s.name, s.shape)
    sig.add_out("loss", ())
    sig.add_out("correct", ())
    arts.append((f"{model.name}_ste_train", ste_train, sig))

    # ---- lrp ----
    sig = Sig()
    _param_sig(sig, model)
    sig.add_in("x", xshape)
    sig.add_in("y", (bsz,), "i32")
    sig.add_in("eqw", ())

    def lrp(*args):
        (p,), rest = unflatten(args, [names])
        x, y, eqw = rest
        rws = M.lrp_step(model, p, x, y, eqw)
        return tuple(rws[n] for n in qnames)

    for n in qnames:
        sig.add_out("r_" + n, shp[n])
    arts.append((f"{model.name}_lrp", lrp, sig))

    # ---- eval ----
    sig = Sig()
    _param_sig(sig, model)
    sig.add_in("x", xshape)
    sig.add_in("y", (bsz,), "i32")

    def ev(*args):
        (p,), rest = unflatten(args, [names])
        x, y = rest
        return M.eval_step(model, p, x, y)

    sig.add_out("loss", ())
    sig.add_out("correct", ())
    arts.append((f"{model.name}_eval", ev, sig))

    # ---- eval_actq (Fig. 1 activation-quantization probe) ----
    if model.name in ("mlp_gsc", "vgg_cifar"):
        sig = Sig()
        _param_sig(sig, model)
        sig.add_in("x", xshape)
        sig.add_in("y", (bsz,), "i32")
        sig.add_in("abits", ())
        fn = M.eval_actq_mlp if model.name == "mlp_gsc" else M.eval_actq_vgg

        def ev_actq(*args, _fn=fn):
            (p,), rest = unflatten(args, [names])
            x, y, abits = rest
            return _fn(model, p, x, y, abits)

        sig.add_out("loss", ())
        sig.add_out("correct", ())
        arts.append((f"{model.name}_eval_actq", ev_actq, sig))

    # ---- eval_q: deployment-form gather eval (MLP only) ----
    if model.name == "mlp_gsc":
        onames = [s.name for s in specs if not s.quantize]
        sig = Sig()
        for n in qnames:
            sig.add_in("idx_" + n, shp[n], "i32")
        for n in qnames:
            sig.add_in("cb_" + n, (K_MAX,))
        for n in onames:
            sig.add_in("p_" + n, shp[n])
        sig.add_in("x", xshape)
        sig.add_in("y", (bsz,), "i32")

        def ev_q(*args):
            (idx, cbs, po), rest = unflatten(args, [qnames, qnames, onames])
            x, y = rest
            return M.eval_gather_mlp(model, po, idx, cbs, x, y)

        sig.add_out("loss", ())
        sig.add_out("correct", ())
        arts.append((f"{model.name}_eval_q", ev_q, sig))

    return arts


def build_assign_artifacts():
    arts = []
    for n in ASSIGN_BUCKETS:
        sig = Sig()
        sig.add_in("w", (n,))
        sig.add_in("r", (n,))
        sig.add_in("mask", (n,))
        sig.add_in("centroids", (K_MAX,))
        sig.add_in("cvalid", (K_MAX,))
        sig.add_in("lam", ())

        def assign(w, r, mask, cen, cv, lam):
            return ecqx_assign.assign_full(w, r, mask, cen, cv, lam)

        sig.add_out("idx", (n,), "i32")
        sig.add_out("qw", (n,))
        sig.add_out("counts", (K_MAX,))
        arts.append((f"assign_{n}", assign, sig))
    return arts


def source_hash():
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    files = [os.path.join(base, "model.py"), os.path.join(base, "aot.py")]
    kdir = os.path.join(base, "kernels")
    files += sorted(
        os.path.join(kdir, f) for f in os.listdir(kdir) if f.endswith(".py")
    )
    for f in files:
        with open(f, "rb") as fh:
            h.update(fh.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--models",
        default="mlp_gsc,vgg_cifar,vgg_cifar_bn,resnet_voc",
        help="comma-separated model list",
    )
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest_path = os.path.join(outdir, "manifest.txt")
    h = source_hash()

    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            first = f.readline().strip()
        if first == f"hash {h}":
            ok = True
            with open(manifest_path) as f:
                for line in f:
                    if line.startswith("artifact "):
                        fname = line.split("file=")[1].strip()
                        if not os.path.exists(os.path.join(outdir, fname)):
                            ok = False
            if ok:
                print(f"artifacts up to date (hash {h})")
                return
    model_names = args.models.split(",")

    lines = [f"hash {h}"]
    all_arts = []
    for mn in model_names:
        model = M.get_model(mn)
        lines.append(
            f"model {model.name} batch={model.batch} "
            f"classes={model.num_classes} "
            f"input={_fmt_shape(model.input_shape)}"
        )
        for s in model.param_specs():
            lines.append(
                f"param {s.name} f32 {_fmt_shape(s.shape)} "
                f"init={s.init} quant={1 if s.quantize else 0}"
            )
        all_arts += build_model_artifacts(model)
    all_arts += build_assign_artifacts()
    lines.append(f"kmax {K_MAX}")
    lines.append("buckets " + ",".join(str(b) for b in ASSIGN_BUCKETS))

    for name, fn, sig in all_arts:
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        print(f"lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*sig.in_specs())
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        lines.append(f"artifact {name} file={fname}")
        for n, d, s in sig.ins:
            lines.append(f"in {n} {d} {_fmt_shape(s)}")
        for n, d, s in sig.outs:
            lines.append(f"out {n} {d} {_fmt_shape(s)}")
        lines.append("end")

    with open(manifest_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {len(all_arts)} artifacts + manifest to {outdir}")


if __name__ == "__main__":
    main()
