"""Algorithmic invariants of the ECQ^x assignment (jnp level), mirroring
the rust property suite so both implementations pin the same semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ecqx_assign, ref
from compile.kernels.ecqx_assign import K_MAX

settings.register_profile("ci2", deadline=None, max_examples=10)
settings.load_profile("ci2")


def codebook(bits, step):
    cen = np.zeros(K_MAX, np.float32)
    cv = np.zeros(K_MAX, np.float32)
    cv[0] = 1.0
    for k in range(1, (1 << (bits - 1))):
        cen[2 * k - 1], cen[2 * k] = k * step, -k * step
        cv[2 * k - 1] = cv[2 * k] = 1.0
    return jnp.asarray(cen), jnp.asarray(cv)


def fitted(w, bits):
    step = float(np.max(np.abs(w))) / ((1 << (bits - 1)) - 1)
    return codebook(bits, max(step, 1e-6))


@given(seed=st.integers(0, 2**31), bits=st.integers(2, 5))
def test_lambda_zero_is_nearest_neighbour(seed, bits):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, 1024).astype(np.float32)
    cen, cv = fitted(w, bits)
    ones = jnp.ones(1024, jnp.float32)
    idx, qw, _ = ecqx_assign.assign_full(jnp.asarray(w), ones, ones, cen, cv, 0.0)
    # every weight must sit in its closest valid centroid
    cen_np, cv_np = np.asarray(cen), np.asarray(cv)
    d = (w[:, None] - cen_np[None, :]) ** 2 + (1 - cv_np)[None, :] * 1e30
    np.testing.assert_array_equal(np.asarray(idx), d.argmin(axis=1))


@given(seed=st.integers(0, 2**31))
def test_sparsity_monotone_in_lambda(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, 2048).astype(np.float32)
    cen, cv = fitted(w, 4)
    ones = jnp.ones(2048, jnp.float32)
    # skip draws where the zero cluster is not the NN mode
    i0, _, c0 = ecqx_assign.assign_full(jnp.asarray(w), ones, ones, cen, cv, 0.0)
    if int(np.asarray(c0).argmax()) != 0:
        return
    last = -1.0
    for lam in [0.0, 1e-5, 1e-4, 5e-4]:
        idx, _, _ = ecqx_assign.assign_full(jnp.asarray(w), ones, ones, cen, cv, lam)
        sp = float(np.mean(np.asarray(idx) == 0))
        assert sp >= last - 1e-9, f"sparsity dropped at lam={lam}"
        last = sp


@given(seed=st.integers(0, 2**31))
def test_relevance_monotone(seed):
    # raising a weight's relevance factor can only move it OUT of the zero
    # cluster, never into it
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, 512).astype(np.float32)
    cen, cv = fitted(w, 4)
    ones = jnp.ones(512, jnp.float32)
    lam = 2e-4
    lo, _, _ = ecqx_assign.assign_full(
        jnp.asarray(w), 0.3 * ones, ones, cen, cv, lam
    )
    hi, _, _ = ecqx_assign.assign_full(
        jnp.asarray(w), 3.0 * ones, ones, cen, cv, lam
    )
    lo, hi = np.asarray(lo), np.asarray(hi)
    # weights kept (non-zero) at low relevance must also be kept at high
    moved_in = np.logical_and(lo != 0, hi == 0).sum()
    assert moved_in == 0, f"{moved_in} weights moved INTO zero as relevance rose"


def test_counts_match_idx():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.1, 4096).astype(np.float32)
    cen, cv = fitted(w, 3)
    mask = jnp.asarray((np.arange(4096) < 3000).astype(np.float32))
    r = jnp.ones(4096, jnp.float32)
    idx, qw, counts = ecqx_assign.assign_full(jnp.asarray(w), r, mask, cen, cv, 1e-4)
    idx, counts = np.asarray(idx), np.asarray(counts)
    for c in range(K_MAX):
        expect = np.sum((idx == c) & (np.arange(4096) < 3000))
        # zero cluster also absorbs the masked padding in idx, but counts
        # must only reflect valid elements
        if c == 0:
            assert counts[c] == np.sum((idx == 0) & (np.arange(4096) < 3000))
        else:
            assert counts[c] == expect


def test_qw_consistent_with_idx():
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.1, 1024).astype(np.float32)
    cen, cv = fitted(w, 5)
    ones = jnp.ones(1024, jnp.float32)
    idx, qw, _ = ecqx_assign.assign_full(jnp.asarray(w), ones, ones, cen, cv, 1e-4)
    np.testing.assert_allclose(
        np.asarray(qw), np.asarray(cen)[np.asarray(idx)], rtol=1e-6
    )


def test_jnp_ref_and_pallas_agree_on_large_bucket():
    # the largest bucket exercises the multi-block grid path
    rng = np.random.default_rng(2)
    n = 16384
    w = rng.normal(0, 0.1, n).astype(np.float32)
    r = rng.uniform(0.5, 2.0, n).astype(np.float32)
    cen, cv = fitted(w, 4)
    ones = jnp.ones(n, jnp.float32)
    i1, q1, c1 = ecqx_assign.assign_full(
        jnp.asarray(w), jnp.asarray(r), ones, cen, cv, 3e-4
    )
    i2, q2, c2 = ref.assign_ref(jnp.asarray(w), jnp.asarray(r), ones, cen, cv, 3e-4)
    mism = int(np.sum(np.asarray(i1) != np.asarray(i2)))
    assert mism <= 16, mism
    np.testing.assert_allclose(np.asarray(c1).sum(), n)
