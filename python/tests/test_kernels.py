"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/seeds; assert_allclose against ref.py — the core
correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ecqx_assign, lrp_dense, qdense, ref

settings.register_profile("ci", deadline=None, max_examples=12)
settings.load_profile("ci")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# matmul / qdense
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(
        qdense.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


@given(
    m=st.integers(1, 64),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31),
)
def test_qdense_bias_and_vjp(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, w = rand(rng, m, k), rand(rng, k, n)
    b = rand(rng, n)
    np.testing.assert_allclose(
        qdense.qdense(a, w, b), ref.qdense_ref(a, w, b), rtol=1e-4, atol=1e-4
    )
    # gradient flows through the custom VJP and matches jnp
    f_pallas = lambda aa, ww: jnp.sum(qdense.qdense(aa, ww, b) ** 2)
    f_ref = lambda aa, ww: jnp.sum(ref.qdense_ref(aa, ww, b) ** 2)
    g1 = jax.grad(f_pallas, argnums=(0, 1))(a, w)
    g2 = jax.grad(f_ref, argnums=(0, 1))(a, w)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=1e-3, atol=1e-3)


def test_qdense_gather_dequantizes():
    rng = np.random.default_rng(0)
    a = rand(rng, 8, 16)
    codebook = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 32, size=(16, 4)), jnp.int32)
    b = rand(rng, 4)
    np.testing.assert_allclose(
        qdense.qdense_gather(a, idx, codebook, b),
        ref.qdense_gather_ref(a, idx, codebook, b),
        rtol=1e-4,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# lrp_dense
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 96),
    i=st.integers(1, 160),
    j=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_lrp_dense_matches_ref(b, i, j, seed):
    rng = np.random.default_rng(seed)
    a, s, w = rand(rng, b, i), rand(rng, b, j), rand(rng, i, j)
    np.testing.assert_allclose(
        lrp_dense.lrp_dense_rw(a, s, w),
        ref.lrp_dense_rw_ref(a, s, w),
        rtol=1e-3,
        atol=1e-4,
    )


def test_lrp_dense_explicit_small():
    # hand-computed 1-sample case: R_w[i,j] = a_i * w_ij * s_j
    a = jnp.asarray([[2.0, -1.0]])
    s = jnp.asarray([[0.5, 3.0]])
    w = jnp.asarray([[1.0, 2.0], [4.0, -2.0]])
    expect = np.array([[2 * 1 * 0.5, 2 * 2 * 3], [-1 * 4 * 0.5, -1 * -2 * 3]])
    np.testing.assert_allclose(lrp_dense.lrp_dense_rw(a, s, w), expect, rtol=1e-6)


def test_stabilize_sign_convention():
    z = jnp.asarray([1.0, -1.0, 0.0])
    out = np.asarray(lrp_dense.stabilize(z, 0.1))
    np.testing.assert_allclose(out, [1.1, -1.1, 0.1])


# ---------------------------------------------------------------------------
# ecqx_assign
# ---------------------------------------------------------------------------


def make_codebook(bits, step):
    cen = np.zeros(ecqx_assign.K_MAX, np.float32)
    cv = np.zeros(ecqx_assign.K_MAX, np.float32)
    cv[0] = 1.0
    side = (1 << (bits - 1)) - 1
    for k in range(1, side + 1):
        cen[2 * k - 1] = k * step
        cen[2 * k] = -k * step
        cv[2 * k - 1] = cv[2 * k] = 1.0
    return jnp.asarray(cen), jnp.asarray(cv)


@given(
    n=st.sampled_from([256, 1024, 8192, 16384]),
    bits=st.integers(2, 5),
    lam=st.floats(0.0, 1e-3),
    frac_pad=st.floats(0.0, 0.4),
    seed=st.integers(0, 2**31),
)
def test_assign_matches_ref(n, bits, lam, frac_pad, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.1, n), jnp.float32)
    r = jnp.asarray(rng.uniform(0.2, 3.0, n), jnp.float32)
    nvalid = max(1, int(n * (1 - frac_pad)))
    mask = jnp.asarray((np.arange(n) < nvalid).astype(np.float32))
    step = float(jnp.max(jnp.abs(w))) / ((1 << (bits - 1)) - 1)
    cen, cv = make_codebook(bits, step)
    i1, q1, c1 = ecqx_assign.assign_full(w, r, mask, cen, cv, lam)
    i2, q2, c2 = ref.assign_ref(w, r, mask, cen, cv, lam)
    # ties may break differently in fused vs unfused fp32: allow a few
    mism = int(np.sum(np.asarray(i1) != np.asarray(i2)))
    assert mism <= max(1, n // 1000), f"{mism} mismatches"
    np.testing.assert_allclose(np.asarray(c1).sum(), nvalid)


def test_assign_relevance_semantics():
    # zero-relevance weight -> pruned; high-relevance -> kept
    n = 256
    w = jnp.full((n,), 0.09, jnp.float32)
    cen, cv = make_codebook(2, 0.1)
    mask = jnp.ones((n,), jnp.float32)
    r = jnp.ones((n,), jnp.float32).at[0].set(0.0).at[1].set(100.0)
    # lambda strong enough to pull the 0.09s into the (popular) +0.1 slot;
    # relevance overrides for the two special entries
    idx, qw, _ = ecqx_assign.assign_full(w, r, mask, cen, cv, 0.0)
    idx = np.asarray(idx)
    assert idx[0] == 0, "zero relevance must be pruned"
    assert idx[1] == 1, "high relevance must be kept"
    assert np.all(idx[2:] == 1), "neutral weights go to nearest neighbour"


def test_assign_entropy_pull():
    # mostly-zero weights + one borderline: entropy flips it at high lambda
    rng = np.random.default_rng(1)
    w = np.full(1024, 0.01, np.float32)
    w[0] = 0.055  # nearest to +0.1 at step 0.1
    cen, cv = make_codebook(2, 0.1)
    mask = jnp.ones((1024,), jnp.float32)
    r = jnp.ones((1024,), jnp.float32)
    i_lo, _, _ = ecqx_assign.assign_full(jnp.asarray(w), r, mask, cen, cv, 0.0)
    i_hi, _, _ = ecqx_assign.assign_full(jnp.asarray(w), r, mask, cen, cv, 0.05)
    assert int(np.asarray(i_lo)[0]) == 1
    assert int(np.asarray(i_hi)[0]) == 0


def test_cluster_probs_mass():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.1, 2048), jnp.float32)
    mask = jnp.asarray((np.arange(2048) < 1500).astype(np.float32))
    cen, cv = make_codebook(4, 0.02)
    probs, counts = ecqx_assign.cluster_probs(w, mask, cen, cv)
    np.testing.assert_allclose(float(jnp.sum(counts)), 1500.0)
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-5)
    # invalid slots receive nothing
    assert float(jnp.sum(jnp.asarray(counts) * (1 - cv))) == 0.0
