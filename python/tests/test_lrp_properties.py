"""LRP invariants (Sec. 4.1): conservation, rule semantics, composite
behaviour across the model families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def test_dense_eps_conservation():
    # For a linear layer with zero bias, relevance is conserved:
    # sum_ij R_w = sum_j R_out (small eps absorption aside).
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(0.5, 1.0, (6, 10)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.4, (10, 4)), jnp.float32)
    b = jnp.zeros(4, jnp.float32)
    r_out = jnp.asarray(rng.uniform(0, 1, (6, 4)), jnp.float32)
    r_in, r_w = M.lrp_dense_eps(a, w, b, r_out)
    np.testing.assert_allclose(
        float(jnp.sum(r_w)), float(jnp.sum(r_out)), rtol=1e-3
    )
    np.testing.assert_allclose(
        float(jnp.sum(r_in)), float(jnp.sum(r_out)), rtol=1e-3
    )


def test_conv_ab_conservation():
    # alpha - beta = 1 keeps relevance approximately conserved through a
    # conv layer (bias zero, eps small).
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(0.3, 1.0, (2, 8, 8, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.3, (3, 3, 3, 5)), jnp.float32)
    b = jnp.zeros(5, jnp.float32)
    r_out = jnp.asarray(rng.uniform(0, 1, (2, 8, 8, 5)), jnp.float32)
    r_in, r_w = M.lrp_conv_ab(a, w, b, r_out)
    total = float(jnp.sum(r_out))
    np.testing.assert_allclose(float(jnp.sum(r_in)), total, rtol=0.05)
    np.testing.assert_allclose(float(jnp.sum(r_w)), total, rtol=0.05)


def test_conv_ab_beta_branch_vanishes_on_positive_paths():
    # With purely positive inputs and weights the beta branch is empty, so
    # the rule degenerates to alpha * proportional decomposition: total
    # relevance = alpha * sum(R_out) (the known alpha-beta imbalance when
    # a layer has no negative contributions).
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(0.1, 1.0, (1, 6, 6, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.05, 0.3, (3, 3, 2, 4)), jnp.float32)
    b = jnp.zeros(4, jnp.float32)
    r_out = jnp.ones((1, 6, 6, 4), jnp.float32)
    r_in, r_w = M.lrp_conv_ab(a, w, b, r_out)
    assert float(jnp.min(r_w)) >= -1e-4
    np.testing.assert_allclose(
        float(jnp.sum(r_in)), M.ALPHA * float(jnp.sum(r_out)), rtol=0.02
    )
    np.testing.assert_allclose(
        float(jnp.sum(r_w)), M.ALPHA * float(jnp.sum(r_out)), rtol=0.02
    )


def test_maxpool_winner_take_all():
    a = jnp.zeros((1, 4, 4, 1), jnp.float32).at[0, 1, 1, 0].set(5.0)
    r_out = jnp.ones((1, 2, 2, 1), jnp.float32)
    r_in = M.lrp_maxpool(a, r_out)
    # the single max element of window (0,0) receives its relevance
    np.testing.assert_allclose(float(r_in[0, 1, 1, 0]), 1.0, rtol=1e-4)
    # nothing leaks to zero elements
    assert float(jnp.sum(jnp.abs(r_in))) < 1.0 + 1e-3 + 3.0  # other windows all-zero


def test_add_split_proportional():
    x1 = jnp.asarray([3.0])
    x2 = jnp.asarray([1.0])
    r1, r2 = M.lrp_add(x1, x2, jnp.asarray([4.0]))
    np.testing.assert_allclose(float(r1[0]), 3.0, rtol=1e-4)
    np.testing.assert_allclose(float(r2[0]), 1.0, rtol=1e-4)


def test_gap_distributes_by_contribution():
    a = jnp.ones((1, 2, 2, 1), jnp.float32).at[0, 0, 0, 0].set(4.0)
    r_out = jnp.ones((1, 1), jnp.float32)
    r_in = M.lrp_gap(a, r_out)
    np.testing.assert_allclose(float(jnp.sum(r_in)), 1.0, rtol=1e-4)
    assert float(r_in[0, 0, 0, 0]) > float(r_in[0, 1, 1, 0])


def test_relevance_init_modes():
    logits = jnp.asarray([[1.0, 2.0, -3.0], [0.5, -1.0, 4.0]])
    y = jnp.asarray([1, 2], jnp.int32)
    r_eq = M.lrp_relevance_init(logits, y, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(r_eq), [[0, 1, 0], [0, 0, 1]])
    r_sc = M.lrp_relevance_init(logits, y, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(r_sc), [[0, 2, 0], [0, 0, 4]])


@pytest.mark.parametrize("name", ["mlp_gsc", "vgg_cifar_bn", "resnet_voc"])
def test_model_lrp_total_relevance_reasonable(name):
    # Composite LRP over the whole model: total per-weight relevance stays
    # within a small factor of the initial relevance (eps/bias absorption
    # and the alpha-beta split prevent exact conservation).
    m = M.get_model(name)
    rng = np.random.default_rng(3)
    p = {}
    for s in m.param_specs():
        if s.init == "he_in":
            fan_in = int(np.prod(s.shape[:-1])) or 1
            p[s.name] = jnp.asarray(
                rng.normal(0, np.sqrt(2.0 / fan_in), s.shape), jnp.float32
            )
        elif s.init == "ones":
            p[s.name] = jnp.ones(s.shape, jnp.float32)
        else:
            p[s.name] = jnp.zeros(s.shape, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4,) + m.input_shape), jnp.float32)
    y = jnp.asarray(rng.integers(0, m.num_classes, 4), jnp.int32)
    rws = m.lrp(p, x, y, jnp.float32(1.0))
    total = sum(float(jnp.sum(rw)) for rw in rws.values())
    n_layers = len(rws)
    # initial relevance is 1 per sample; each quantized layer aggregates
    # a comparable share — demand the right order of magnitude
    assert np.isfinite(total)
    assert abs(total) < 50.0 * n_layers, total
