"""L2 model correctness: shapes, training dynamics, STE semantics, eval
variants — for all four model families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def init_params(m, seed=0):
    rng = np.random.default_rng(seed)
    p = {}
    for s in m.param_specs():
        if s.init == "he_in":
            fan_in = int(np.prod(s.shape[:-1])) or 1
            p[s.name] = jnp.asarray(
                rng.normal(0, np.sqrt(2.0 / fan_in), s.shape), jnp.float32
            )
        elif s.init == "ones":
            p[s.name] = jnp.ones(s.shape, jnp.float32)
        else:
            p[s.name] = jnp.zeros(s.shape, jnp.float32)
    return p


def batch_for(m, n=8, seed=1):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,) + m.input_shape), jnp.float32)
    y = jnp.asarray(rng.integers(0, m.num_classes, n), jnp.int32)
    return x, y


ALL_MODELS = ["mlp_gsc", "vgg_cifar", "vgg_cifar_bn", "resnet_voc"]


@pytest.mark.parametrize("name", ALL_MODELS)
def test_forward_shapes(name):
    m = M.get_model(name)
    p = init_params(m)
    x, y = batch_for(m)
    logits = m.forward(p, x)
    assert logits.shape == (8, m.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL_MODELS)
def test_lrp_covers_quantized_params(name):
    m = M.get_model(name)
    p = init_params(m)
    x, y = batch_for(m)
    rws = m.lrp(p, x, y, jnp.float32(0.0))
    qnames = {s.name for s in m.param_specs() if s.quantize}
    assert set(rws) == qnames
    for k, rw in rws.items():
        assert rw.shape == p[k].shape
        assert bool(jnp.all(jnp.isfinite(rw))), k


def test_fp_training_reduces_loss():
    m = M.get_model("mlp_gsc")
    p = init_params(m)
    mm = {k: jnp.zeros_like(v) for k, v in p.items()}
    vv = {k: jnp.zeros_like(v) for k, v in p.items()}
    x, y = batch_for(m, 32)
    step = jax.jit(
        lambda p, mm, vv, t: M.fp_train_step(
            m, p, mm, vv, x, y, t, jnp.float32(1e-3)
        )
    )
    losses = []
    t = 0.0
    for _ in range(12):
        t += 1.0
        p, mm, vv, loss, corr = step(p, mm, vv, jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_ste_updates_fp_not_q():
    m = M.get_model("mlp_gsc")
    p = init_params(m)
    qnames = [s.name for s in m.param_specs() if s.quantize]
    qw = {k: jnp.round(p[k] * 16) / 16 for k in qnames}
    mm = {k: jnp.zeros_like(v) for k, v in p.items()}
    vv = {k: jnp.zeros_like(v) for k, v in p.items()}
    x, y = batch_for(m, 16)
    np_, nm, nv, loss, corr = M.ste_train_step(
        m, p, qw, mm, vv, x, y, jnp.float32(1.0), jnp.float32(1e-3), jnp.float32(1.0)
    )
    # FP weights moved
    moved = sum(
        float(jnp.max(jnp.abs(np_[k] - p[k]))) for k in qnames
    )
    assert moved > 0.0
    # the gradient that moved them was computed at the quantized weights:
    # re-run with gs=0 (no scaling) and check the loss equals the forward
    # pass through qw
    logits = m.forward({**p, **qw}, x)
    np.testing.assert_allclose(
        float(loss), float(M.softmax_xent(logits, y)), rtol=1e-5
    )


def test_grad_scaling_flag_changes_update():
    m = M.get_model("mlp_gsc")
    p = init_params(m)
    qnames = [s.name for s in m.param_specs() if s.quantize]
    qw = {k: jnp.round(p[k] * 4) / 4 for k in qnames}
    mm = {k: jnp.zeros_like(v) for k, v in p.items()}
    vv = {k: jnp.zeros_like(v) for k, v in p.items()}
    x, y = batch_for(m, 16)
    args = (m, p, qw, mm, vv, x, y, jnp.float32(1.0), jnp.float32(1e-3))
    p_on, *_ = M.ste_train_step(*args, jnp.float32(1.0))
    p_off, *_ = M.ste_train_step(*args, jnp.float32(0.0))
    diff = sum(float(jnp.max(jnp.abs(p_on[k] - p_off[k]))) for k in qnames)
    assert diff > 0.0, "grad scaling must change the update"


def test_eval_counts_correct():
    m = M.get_model("mlp_gsc")
    p = init_params(m)
    x, y = batch_for(m, 64)
    loss, correct = M.eval_step(m, p, x, y)
    logits = m.forward(p, x)
    expect = float(jnp.sum((jnp.argmax(logits, axis=1) == y)))
    assert float(correct) == expect
    assert 0 <= float(correct) <= 64


def test_eval_gather_equals_dense():
    m = M.get_model("mlp_gsc")
    p = init_params(m)
    qnames = [s.name for s in m.param_specs() if s.quantize]
    onames = [s.name for s in m.param_specs() if not s.quantize]
    rng = np.random.default_rng(5)
    idx, cbs, qws = {}, {}, {}
    for k in qnames:
        cb = jnp.asarray(np.linspace(-0.5, 0.5, 32), jnp.float32)
        ii = jnp.asarray(rng.integers(0, 32, p[k].shape), jnp.int32)
        idx[k], cbs[k] = ii, cb
        qws[k] = jnp.take(cb, ii)
    x, y = batch_for(m, 16)
    l1, c1 = M.eval_gather_mlp(m, {k: p[k] for k in onames}, idx, cbs, x, y)
    l2, c2 = M.eval_step(m, {**{k: p[k] for k in onames}, **qws}, x, y)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    assert float(c1) == float(c2)


def test_actq_low_bits_degrade():
    m = M.get_model("mlp_gsc")
    p = init_params(m, seed=3)
    x, y = batch_for(m, 64, seed=4)
    l16, _ = M.eval_actq_mlp(m, p, x, y, jnp.float32(16.0))
    l_ref, _ = M.eval_step(m, p, x, y)
    # 16-bit activations ~ exact
    np.testing.assert_allclose(float(l16), float(l_ref), rtol=1e-2)
    l2, _ = M.eval_actq_mlp(m, p, x, y, jnp.float32(2.0))
    assert float(l2) > float(l_ref) - 1e-6


def test_adam_matches_reference():
    # one Adam step against a hand-rolled numpy implementation
    p = jnp.asarray([1.0, -2.0, 3.0])
    g = jnp.asarray([0.1, -0.2, 0.3])
    m0 = jnp.zeros(3)
    v0 = jnp.zeros(3)
    p1, m1, v1 = M.adam_update(p, g, m0, v0, jnp.float32(1.0), jnp.float32(0.01))
    mm = 0.1 * np.asarray(g)
    vv = 0.001 * np.asarray(g) ** 2
    mh = mm / (1 - 0.9)
    vh = vv / (1 - 0.999)
    expect = np.asarray(p) - 0.01 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(p1, expect, rtol=1e-5)
