"""AOT pipeline: signatures, HLO text lowering, manifest consistency."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_bucket_for_monotone():
    assert aot.bucket_for(1) == 1024
    assert aot.bucket_for(1024) == 1024
    assert aot.bucket_for(1025) == 2048
    assert aot.bucket_for(524288) == 524288
    with pytest.raises(ValueError):
        aot.bucket_for(10**7)


def test_every_quantized_layer_fits_a_bucket():
    for name in M.MODELS:
        m = M.get_model(name)
        for s in m.param_specs():
            if s.quantize:
                n = int(np.prod(s.shape))
                assert aot.bucket_for(n) >= n


def test_signatures_consistent():
    m = M.get_model("mlp_gsc")
    arts = aot.build_model_artifacts(m)
    names = [a[0] for a in arts]
    for suffix in ["fp_train", "ste_train", "lrp", "eval", "eval_actq", "eval_q"]:
        assert f"mlp_gsc_{suffix}" in names
    by_name = {a[0]: a for a in arts}
    _, _, sig = by_name["mlp_gsc_ste_train"]
    in_names = [n for n, _, _ in sig.ins]
    # FP params, quantized copies, moments, batch, scalars — in this order
    assert in_names[0] == "p_w0"
    assert "q_w0" in in_names and "m_w0" in in_names and "v_w0" in in_names
    assert in_names[-5:] == ["x", "y", "t", "lr", "gs"]
    out_names = [n for n, _, _ in sig.outs]
    assert out_names[-2:] == ["loss", "correct"]
    # outputs mirror the parameter inputs
    n_params = len(m.param_specs())
    assert len(out_names) == 3 * n_params + 2


def test_lowering_small_artifact_produces_hlo_text():
    # lower the smallest assign artifact and check it is parseable HLO text
    arts = aot.build_assign_artifacts()
    name, fn, sig = arts[0]
    lowered = jax.jit(fn).lower(*sig.in_specs())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # all six inputs appear as parameters
    assert text.count("parameter(") >= 6


def test_source_hash_stable():
    h1 = aot.source_hash()
    h2 = aot.source_hash()
    assert h1 == h2 and len(h1) == 16


def test_built_manifest_matches_models():
    # if the artifacts have been built, validate the manifest contents
    mdir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(mdir, "manifest.txt")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    text = open(mpath).read()
    assert text.startswith("hash ")
    for name in M.MODELS:
        assert f"model {name} " in text
    for b in aot.ASSIGN_BUCKETS:
        assert f"artifact assign_{b} " in text
        assert os.path.exists(os.path.join(mdir, f"assign_{b}.hlo.txt"))
