//! Deployment-form inference: quantize, ship the `.ecqx` container, and
//! serve with *integer* weights — centroid indices + a per-layer codebook
//! dequantized through the L1 Pallas gather kernel (`mlp_gsc_eval_q`),
//! the "LUT + integer weights" execution mode the paper targets for
//! hardware (Sec. 5.2.3).
//!
//! Run: `cargo run --release --example deploy_integer_inference`

use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::coordinator::trainer::evaluate;
use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::metrics::Meter;
use ecqx::util::Timer;

fn main() -> anyhow::Result<()> {
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(&model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);

    // quantize to 2 bit — the ternary-and-beyond deployment sweet spot
    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 2,
            lambda: 0.4,
            p: 0.1,
            ..Default::default()
        },
        epochs: 2,
        lr: 4e-4,
        verbose: false,
        ..Default::default()
    };
    let mut state = pre.state;
    QatTrainer::new(cfg).run(&engine, &mut state, &train_dl, &val_dl)?;

    // f32 dequantized-eval reference
    let dense = evaluate(&engine, &state, &val_dl, ParamSource::Quantized)?;

    // integer gather-eval: same numbers through idx + codebook
    let art = engine.manifest.artifact("mlp_gsc_eval_q")?.clone();
    let mut meter = Meter::new();
    let t = Timer::start();
    for batch in val_dl.epoch(0) {
        let inputs =
            bind_inputs(&art, &state, ParamSource::Quantized, Some(&batch), &Scalars::default())?;
        let outs = engine.call_named(&art.name, &inputs)?;
        meter.update(
            outs["loss"].as_f32().as_scalar(),
            outs["correct"].as_f32().as_scalar(),
            batch.batch,
        );
    }
    let wall = t.elapsed_s();
    println!("2-bit integer-weight deployment (indices + LUT):");
    println!("  dense  eval acc = {:.4}", dense.accuracy);
    println!("  gather eval acc = {:.4}", meter.accuracy());
    assert!((dense.accuracy - meter.accuracy()).abs() < 1e-9, "paths must agree");
    println!(
        "  served {} samples in {:.2}s ({:.0} samples/s)",
        meter.samples,
        wall,
        meter.samples as f64 / wall
    );
    println!(
        "  weights per layer: 2-bit indices, {}-entry codebook",
        state.qlayers["w0"].codebook.n_valid()
    );
    Ok(())
}
