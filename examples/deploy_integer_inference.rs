//! Deployment-form inference: quantize, then serve with *integer*
//! weights — centroid indices + a per-layer codebook, executed by the
//! sparse LUT kernel (`linalg::lut_matmul`, DESIGN.md §2.7): the
//! `mlp_gsc_eval_q` artifact's dense layers pack the indices into
//! CSR panels that structurally skip the zero centroid, accumulate
//! per-centroid input sums, and apply the ≤31-entry codebook as a
//! final lookup multiply. This is the "LUT + integer weights"
//! execution mode the paper targets for hardware (Sec. 5.2.3) — the
//! dense weight matrix is never materialized, and arithmetic scales
//! with nnz + centroid count instead of dense k·n FMAs.
//!
//! Run: `cargo run --release --example deploy_integer_inference`

use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::coordinator::trainer::evaluate;
use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::linalg::{gemm_flops, lut_ops};
use ecqx::metrics::Meter;
use ecqx::util::Timer;

fn main() -> anyhow::Result<()> {
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(&model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);

    // quantize to 2 bit — the ternary-and-beyond deployment sweet spot
    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 2,
            lambda: 0.4,
            p: 0.1,
            ..Default::default()
        },
        epochs: 2,
        lr: 4e-4,
        verbose: false,
        ..Default::default()
    };
    let mut state = pre.state;
    QatTrainer::new(cfg).run(&engine, &mut state, &train_dl, &val_dl)?;

    // f32 dequantized-eval reference (oracle for the LUT path)
    let dense = evaluate(&engine, &state, &val_dl, ParamSource::Quantized)?;

    // integer LUT eval: same predictions through idx + codebook, but the
    // dense layers run the zero-skipping LUT kernel instead of a gather
    let art = engine.manifest.artifact("mlp_gsc_eval_q")?.clone();
    let mut meter = Meter::new();
    let t = Timer::start();
    for batch in val_dl.epoch(0) {
        let inputs =
            bind_inputs(&art, &state, ParamSource::Quantized, Some(&batch), &Scalars::default())?;
        let outs = engine.call_named(&art.name, &inputs)?;
        meter.update(
            outs["loss"].as_f32().as_scalar(),
            outs["correct"].as_f32().as_scalar(),
            batch.batch,
        );
    }
    let wall = t.elapsed_s();
    println!("2-bit integer-weight deployment (indices + LUT):");
    println!("  dense eval acc = {:.4}", dense.accuracy);
    println!("  LUT   eval acc = {:.4}", meter.accuracy());
    // parity vs the dense-dequant oracle: the LUT path reorders the k-sum
    // (per-centroid partials) within the §2.6 envelope, so losses agree to
    // float tolerance and the argmax — hence accuracy — is identical
    assert!((dense.accuracy - meter.accuracy()).abs() < 1e-9, "paths must agree");
    assert!((dense.loss - meter.loss()).abs() < 1e-4, "losses must agree to tolerance");
    println!(
        "  served {} samples in {:.2}s ({:.0} samples/s)",
        meter.samples,
        wall,
        meter.samples as f64 / wall
    );
    println!(
        "  weights per layer: 2-bit indices, {}-entry codebook",
        state.qlayers["w0"].codebook.n_valid()
    );
    // the whole point of the LUT kernel: work scales with nonzero weights
    // and centroid count, not dense k*n FMAs
    let mut lut = 0.0;
    let mut fma = 0.0;
    for ql in state.qlayers.values() {
        if let [k, n] = ql.idx.shape[..] {
            lut += lut_ops(&ql.idx.data, &ql.codebook.values, spec.batch, k, n);
            fma += gemm_flops(spec.batch, k, n);
        }
    }
    println!("  dense-layer work: {:.0} LUT ops vs {:.0} dense flops ({:.1}x less)", lut, fma, fma / lut.max(1.0));
    Ok(())
}
