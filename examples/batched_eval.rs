//! Batched evaluation: quantize one pre-trained model with ECQ and ECQ^x,
//! then score both states in a single pass over the validation loader via
//! `trainer::evaluate_many` — each batch is materialized once and fanned
//! across the states through `Engine::call_batch`.
//!
//! Run: `cargo run --release --example batched_eval` (after `make artifacts`)

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::trainer::{evaluate_many, QatTrainer};
use ecqx::coordinator::{AssignConfig, Method, QatConfig};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::util::Timer;

fn main() -> anyhow::Result<()> {
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(&model, 17);
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);

    // one QAT run per method from the same snapshot
    let mut states = Vec::new();
    for method in [Method::Ecq, Method::Ecqx] {
        let mut state = ecqx::nn::ModelState {
            spec: pre.state.spec.clone(),
            params: pre.state.params.clone(),
            m: pre.state.m.clone(),
            v: pre.state.v.clone(),
            t: 0,
            qlayers: Default::default(),
        };
        let cfg = QatConfig {
            assign: AssignConfig { method, bits: 4, lambda: 8.0, p: 0.2, ..Default::default() },
            epochs: 1,
            lr: model.qat_lr * 4.0,
            verbose: false,
            ..Default::default()
        };
        QatTrainer::new(cfg).run(&engine, &mut state, &train_dl, &val_dl)?;
        states.push(state);
    }

    // one validation pass scoring every state (vs one pass per state)
    let t = Timer::start();
    let refs: Vec<&ecqx::nn::ModelState> = states.iter().collect();
    let results = evaluate_many(&engine, &refs, &val_dl, ParamSource::Quantized, 2)?;
    println!("batched eval of {} states in {:.2}s:", refs.len(), t.elapsed_s());
    for (method, ev) in [Method::Ecq, Method::Ecqx].iter().zip(&results) {
        println!(
            "  {:<5} acc={:.4} (baseline {:.4}, drop {:+.4}) loss={:.4}",
            method.as_str(),
            ev.accuracy,
            pre.baseline_acc,
            ev.accuracy - pre.baseline_acc,
            ev.loss
        );
    }
    Ok(())
}
