//! Quickstart: load the AOT artifacts, quantize a pre-trained MLP with
//! ECQ^x to 4 bit, and print the accuracy / sparsity / compression-ratio
//! summary — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::trainer::evaluate;
use ecqx::coordinator::{
    compressed_size, compression_ratio, AssignConfig, Method, QatConfig, QatTrainer,
};
use ecqx::data::DataLoader;
use ecqx::exp;

fn main() -> anyhow::Result<()> {
    // 1. PJRT engine over the HLO artifacts (python never runs from here on)
    let engine = exp::engine()?;

    // 2. pre-trained FP32 baseline (trained + cached on first use)
    let model = exp::MLP_GSC;
    let pre = exp::pretrained(&engine, &model, 17)?;
    println!(
        "baseline: {} params, val acc {:.4}",
        pre.state.spec.total_params(),
        pre.baseline_acc
    );

    // 3. synthetic GSC data loaders
    let (train, val) = exp::datasets(&model, 17);
    let spec = engine.manifest.model(model.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, 17);
    let val_dl = DataLoader::new(&val, spec.batch, false, 17);

    // 4. ECQ^x quantization-aware training: 4 bit, entropy constraint
    //    lambda, LRP target-sparsity p
    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 4,
            lambda: 10.0,
            p: 0.15,
            ..Default::default()
        },
        epochs: 1,
        lr: 4e-4,
        ..Default::default()
    };
    let mut state = pre.state;
    let outcome = QatTrainer::new(cfg).run(&engine, &mut state, &train_dl, &val_dl)?;

    // 5. results
    let ev = evaluate(&engine, &state, &val_dl, ParamSource::Quantized)?;
    println!("\nquantized: val acc {:.4} (drop {:+.4})", ev.accuracy, ev.accuracy - pre.baseline_acc);
    println!("sparsity:  {:.2}%", outcome.final_sparsity * 100.0);
    println!(
        "size:      {:.1} kB (CR {:.1}x vs {:.1} kB fp32)",
        compressed_size(&state) as f64 / 1000.0,
        compression_ratio(&state),
        state.fp32_bytes() as f64 / 1000.0
    );
    Ok(())
}
