use ecqx::exp;
use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::coordinator::trainer::evaluate;
use ecqx::data::DataLoader;
use ecqx::nn::QLayer;
use ecqx::quant::Codebook;
use ecqx::tensor::{Tensor, TensorI32};
use std::collections::BTreeMap;
fn main() -> anyhow::Result<()> {
    let eng = exp::engine()?;
    let e = exp::MLP_GSC;
    let pre = exp::pretrained(&eng, &e, 17)?;
    let mut state = pre.state;
    let (train, val) = exp::datasets(&e, 17);
    let tdl = DataLoader::new(&train, 128, true, 3);
    let vdl = DataLoader::new(&val, 128, false, 3);
    // accumulate relevances over 16 train batches
    let art = eng.manifest.artifact("mlp_gsc_lrp")?.clone();
    let mut acc: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    for (i, batch) in tdl.epoch(0).enumerate().take(16) {
        let sc = Scalars { eqw: 0.0, ..Default::default() };
        let inputs = bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &sc)?;
        let outs = eng.call_named(&art.name, &inputs)?;
        for (k, v) in outs {
            if let Some(n) = k.strip_prefix("r_") {
                let t = v.into_f32();
                let e = acc.entry(n.to_string()).or_insert_with(|| vec![0.0; t.numel()]);
                for (a, b) in e.iter_mut().zip(&t.data) { *a += b.abs(); }
            }
        }
        let _ = i;
    }
    // per-layer: prune frac by |w| vs by relevance, eval
    for frac in [0.5f64, 0.7, 0.8] {
        for mode in ["magnitude", "relevance"] {
            for name in state.qnames() {
                let w = state.params[&name].clone();
                let score: Vec<f32> = match mode {
                    "magnitude" => w.data.iter().map(|x| x.abs()).collect(),
                    _ => acc[&name].clone(),
                };
                let mut order: Vec<usize> = (0..w.numel()).collect();
                order.sort_by(|&a, &b| score[a].partial_cmp(&score[b]).unwrap());
                let cut = (w.numel() as f64 * frac) as usize;
                let mut qw = w.data.clone();
                let mut idx = vec![1i32; w.numel()];
                for &i in &order[..cut] { qw[i] = 0.0; idx[i] = 0; }
                state.qlayers.insert(name.clone(), QLayer {
                    qw: Tensor::new(w.shape.clone(), qw),
                    idx: TensorI32::new(w.shape.clone(), idx),
                    codebook: Codebook::fit(&w.data, 4),
                });
            }
            let ev = evaluate(&eng, &state, &vdl, ParamSource::Quantized)?;
            println!("prune {:.0}% by {mode:<10} -> acc {:.4}", frac * 100.0, ev.accuracy);
        }
    }
    Ok(())
}
