//! End-to-end driver (DESIGN.md §6): pre-train MLP_GSC from scratch on
//! synthetic Google Speech Commands, run the full ECQ^x 4-bit QAT
//! (hundreds of STE/LRP/assign steps through the PJRT artifacts), log the
//! loss/accuracy/sparsity curves, compress to a `.ecqx` container, reload
//! it and re-evaluate — proving all three layers compose.
//!
//! Run: `cargo run --release --example e2e_mlp_gsc`

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::trainer::{evaluate, Pretrainer};
use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::nn::{checkpoint, ModelState};
use ecqx::util::Timer;

fn main() -> anyhow::Result<()> {
    let t_total = Timer::start();
    let engine = exp::engine()?;
    let model = exp::MLP_GSC;
    let spec = engine.manifest.model(model.name)?.clone();
    let (train, val) = exp::datasets(&model, 4242);
    let train_dl = DataLoader::new(&train, spec.batch, true, 4242);
    let val_dl = DataLoader::new(&val, spec.batch, false, 4242);

    // ---- phase 1: FP32 pre-training from scratch ----
    println!("== phase 1: FP32 pre-training ({} epochs) ==", model.pretrain_epochs);
    let mut state = ModelState::init(&spec, 4242);
    let pre = Pretrainer { lr: model.pretrain_lr, ..Default::default() };
    let curve = pre.run(&engine, &mut state, &train_dl, model.pretrain_epochs)?;
    let baseline = evaluate(&engine, &state, &val_dl, ParamSource::Fp)?;
    println!("loss curve: {:?}", curve.iter().map(|c| (c.0 * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("baseline val acc = {:.4}", baseline.accuracy);

    // ---- phase 2: ECQ^x quantization-aware training ----
    println!("\n== phase 2: ECQ^x 4-bit QAT ==");
    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 4,
            lambda: 10.0,
            p: 0.15,
            ..Default::default()
        },
        epochs: 3,
        lr: 4e-4,
        ..Default::default()
    };
    let outcome = QatTrainer::new(cfg).run(&engine, &mut state, &train_dl, &val_dl)?;
    println!("\nper-epoch curve (loss / val_acc / sparsity):");
    for e in &outcome.epochs {
        println!(
            "  epoch {}: {:.4} / {:.4} / {:.4}",
            e.epoch, e.train_loss, e.val_acc, e.sparsity
        );
    }
    println!("\nphase profile:\n{}", outcome.profile.report());

    // ---- phase 3: compress, reload, verify ----
    println!("== phase 3: compress -> reload -> verify ==");
    let path = std::env::temp_dir().join("e2e_mlp_gsc.ecqx");
    let bytes = checkpoint::save_quantized(&path, &state)?;
    let qm = checkpoint::load_quantized(&path)?;
    let mut reloaded = ModelState::init(&spec, 4242);
    for (name, t) in qm.other {
        reloaded.params.insert(name, t);
    }
    for (name, (idx, cb)) in qm.layers {
        let qw: Vec<f32> = idx.data.iter().map(|&s| cb.values[s as usize]).collect();
        let shape = idx.shape.clone();
        reloaded.qlayers.insert(
            name,
            ecqx::nn::QLayer {
                qw: ecqx::tensor::Tensor::new(shape, qw),
                idx,
                codebook: cb,
            },
        );
    }
    let ev = evaluate(&engine, &reloaded, &val_dl, ParamSource::Quantized)?;
    let fp_kb = state.fp32_bytes() as f64 / 1000.0;
    println!("container: {:.1} kB on disk (CR {:.1}x vs {fp_kb:.1} kB fp32)", bytes as f64 / 1000.0, fp_kb / (bytes as f64 / 1000.0));
    println!(
        "reloaded:  val acc {:.4} (drop {:+.4} vs baseline), sparsity {:.4}",
        ev.accuracy,
        ev.accuracy - baseline.accuracy,
        reloaded.quantized_sparsity()
    );
    println!("\ntotal wall clock: {:.1}s", t_total.elapsed_s());
    assert!(ev.accuracy > 0.3, "end-to-end accuracy sanity check failed");
    std::fs::remove_file(&path).ok();
    Ok(())
}
