//! Codec family comparison on a real quantized model: encode every layer
//! of an ECQ-assigned MLP with the DeepCABAC-style coder and the
//! baselines (bit-packing, Huffman, RLE, CSR size model, deflate), across
//! sparsity levels — the codec-side evidence behind Figs. 9/10 and the
//! paper's "highly compressible" claim.
//!
//! Run: `cargo run --release --example codec_comparison`

use ecqx::codec::compare_codecs;
use ecqx::exp;
use ecqx::metrics::Table;
use ecqx::quant::{assign_ref, Codebook};
use ecqx::tensor::TensorI32;

fn main() -> anyhow::Result<()> {
    let engine = exp::engine()?;
    let pre = exp::pretrained(&engine, &exp::MLP_GSC, 17)?;

    // dimensionless lambda (coordinator semantics: scaled by step² below)
    for (label, lam_dimless) in
        [("low sparsity (lambda=0)", 0.0f32), ("high sparsity (lambda=14)", 14.0)]
    {
        println!("\n== {label} ==");
        let mut table = Table::new(&[
            "layer", "numel", "sparsity", "fp32 kB", "packed", "CABAC", "Huffman",
            "RLE", "CSR", "deflate",
        ]);
        let mut tot = [0usize; 7];
        for name in pre.state.qnames() {
            let w = &pre.state.params[&name];
            let cb = Codebook::fit(&w.data, 4);
            let ones = vec![1.0f32; w.numel()];
            let lam = lam_dimless * cb.step * cb.step;
            let a = assign_ref(&w.data, &ones, &ones, &cb, lam);
            let idx = TensorI32::new(w.shape.clone(), a.idx);
            let zeros = idx.data.iter().filter(|&&i| i == 0).count();
            let cmp = compare_codecs(&idx, 4);
            let kb = |b: usize| format!("{:.1}", b as f64 / 1000.0);
            table.row(&[
                name.clone(),
                w.numel().to_string(),
                format!("{:.3}", zeros as f64 / w.numel() as f64),
                kb(cmp.fp32),
                kb(cmp.packed),
                kb(cmp.cabac),
                kb(cmp.huffman),
                kb(cmp.rle),
                kb(cmp.csr),
                kb(cmp.deflate),
            ]);
            for (t, v) in tot.iter_mut().zip([
                cmp.fp32, cmp.packed, cmp.cabac, cmp.huffman, cmp.rle, cmp.csr,
                cmp.deflate,
            ]) {
                *t += v;
            }
        }
        table.row(&[
            "TOTAL".into(),
            "".into(),
            "".into(),
            format!("{:.1}", tot[0] as f64 / 1000.0),
            format!("{:.1}", tot[1] as f64 / 1000.0),
            format!("{:.1}", tot[2] as f64 / 1000.0),
            format!("{:.1}", tot[3] as f64 / 1000.0),
            format!("{:.1}", tot[4] as f64 / 1000.0),
            format!("{:.1}", tot[5] as f64 / 1000.0),
            format!("{:.1}", tot[6] as f64 / 1000.0),
        ]);
        println!("{}", table.render());
        println!(
            "CABAC compression ratio vs fp32: {:.1}x",
            tot[0] as f64 / tot[2] as f64
        );
    }
    Ok(())
}
